//! Actor mailboxes: lock-free FIFO per priority class, with system messages
//! (down, exit, timeouts) overtaking ordinary traffic — CAF's two-queue
//! design, on CAF's lock-free footing.
//!
//! Layout: two Vyukov-style MPSC lanes (system + normal) plus one atomic
//! state word `count | closed-bit` covering both lanes. The state word
//! makes the hot path lock-free end to end:
//!
//! * `enqueue` is one `fetch_add` (deciding `Closed` / `NeedsSchedule` /
//!   `Stored`) plus a wait-free lane push — no mutex, ever;
//! * `dequeue`/`dequeue_batch` (single consumer: the scheduler slice that
//!   holds the actor's RUNNING state) never lock either; the count
//!   disambiguates "empty" from "producer mid-push", which costs at most a
//!   few spins;
//! * `close` snapshots the count while setting the closed bit, then drains
//!   exactly that many envelopes — racing producers either land inside the
//!   snapshot (and are drained) or observe the bit and get their envelope
//!   back, so nothing is silently dropped.
//!
//! A consumer-private replay deque backs [`Mailbox::push_front`]
//! (un-stashing after a behavior change); it sits logically at the front of
//! the normal lane and is counted in the same state word.

use super::envelope::Envelope;
use crate::concurrent::{spin_backoff, MpscQueue};
use crate::loom_types::{AtomicU64, Ordering, UnsafeCell};
use std::collections::VecDeque;

/// Result of an enqueue, telling the caller whether it must schedule the
/// owning actor.
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub enum EnqueueResult {
    /// Message stored; the mailbox was empty, caller should schedule.
    NeedsSchedule,
    /// Message stored; actor already has work queued.
    Stored,
    /// Mailbox closed (actor terminated); message was rejected.
    Closed,
}

const CLOSED_BIT: u64 = 1 << 63;
const COUNT_MASK: u64 = CLOSED_BIT - 1;

/// Two-priority lock-free mailbox.
///
/// Producers (`enqueue`) may be any threads. The consumer-side operations —
/// `dequeue`, `dequeue_batch`, `try_dequeue_system`, `push_front`,
/// `replay_len`, `requeue_remainder`, `close` — must only be invoked by the
/// single thread currently executing the owning actor (the scheduler
/// guarantees this via the IDLE/SCHEDULED/RUNNING state machine).
pub struct Mailbox {
    /// `count | closed-bit`, counting both lanes plus the replay deque.
    state: AtomicU64,
    system: MpscQueue<Envelope>,
    normal: MpscQueue<Envelope>,
    /// Consumer-private replay queue (un-stash target); logically the front
    /// of the normal lane.
    replay: UnsafeCell<VecDeque<Envelope>>,
}

// SAFETY: the MPSC lanes are Sync; `replay` is only touched by the single
// consumer (see the struct-level contract), and `state` is an atomic.
unsafe impl Send for Mailbox {}
unsafe impl Sync for Mailbox {}

impl Default for Mailbox {
    fn default() -> Self {
        Self::new()
    }
}

impl Mailbox {
    pub fn new() -> Self {
        Mailbox {
            state: AtomicU64::new(0),
            system: MpscQueue::new(),
            normal: MpscQueue::new(),
            replay: UnsafeCell::new(VecDeque::new()),
        }
    }

    /// Multi-producer enqueue; a single atomic RMW decides the result.
    pub fn enqueue(&self, env: Envelope, system: bool) -> EnqueueResult {
        let prev = self.state.fetch_add(1, Ordering::SeqCst);
        if prev & CLOSED_BIT != 0 {
            // close() snapshotted the count before this increment — undo
            // the announcement and bounce the envelope to the caller.
            self.state.fetch_sub(1, Ordering::SeqCst);
            return EnqueueResult::Closed;
        }
        if system {
            self.system.push(env);
        } else {
            self.normal.push(env);
        }
        if prev & COUNT_MASK == 0 {
            EnqueueResult::NeedsSchedule
        } else {
            EnqueueResult::Stored
        }
    }

    /// Push a message back to the *front* of the normal queue (used when a
    /// behavior change un-stashes skipped messages). Consumer-side.
    ///
    /// Returns the envelope when the mailbox is already closed so the
    /// caller can route it to dead-letters instead of losing it.
    pub fn push_front(&self, env: Envelope) -> Result<(), Envelope> {
        // No race with close(): both run on the consumer side.
        if self.state.load(Ordering::Acquire) & CLOSED_BIT != 0 {
            return Err(env);
        }
        // SAFETY: consumer-side contract — exclusive access to `replay`.
        self.replay.with_mut(|r| unsafe { (*r).push_front(env) });
        self.state.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Pop one envelope in priority order: system lane, then replayed
    /// messages, then the normal lane. Consumer-side.
    pub fn dequeue(&self) -> Option<Envelope> {
        let mut spins = 0u32;
        loop {
            let s = self.state.load(Ordering::Acquire);
            if s & COUNT_MASK == 0 {
                return None;
            }
            if let Some(e) = self.pop_any() {
                self.state.fetch_sub(1, Ordering::AcqRel);
                return Some(e);
            }
            // count > 0 but nothing visible: a producer is between its
            // head-swap and next-link — a few cycles, unless it was
            // preempted, hence the occasional yield
            spin_backoff(&mut spins);
        }
    }

    /// Drain up to `max` envelopes into `out` under a single state
    /// transition (one `fetch_sub` for the whole batch) instead of one
    /// decrement per message. Consumer-side. Returns the number drained.
    ///
    /// The batch always has the shape `[system..., ordinary...]`: the
    /// system lane is drained *before* the replay deque and the normal
    /// lane, and never re-probed mid-drain, so a system message linked
    /// while the ordinary lanes drain stays in the lane (it is younger
    /// than everything in the batch; `resume`'s overtake probe picks it
    /// up). Both `resume`'s probe-skip rule and its stash-replay splice
    /// rely on that prefix shape.
    pub fn dequeue_batch(&self, max: usize, out: &mut Vec<Envelope>) -> usize {
        let mut got = 0usize;
        let mut spins = 0u32;
        // phase 1: the system lane
        while got < max {
            let s = self.state.load(Ordering::Acquire);
            if ((s & COUNT_MASK) as usize) <= got {
                break;
            }
            match self.system.pop() {
                Some(e) => {
                    out.push(e);
                    got += 1;
                }
                // the lane looks empty — the remaining count is ordinary
                // traffic (or a mid-push system producer, which then just
                // stays for the overtake probe)
                None => break,
            }
        }
        // phase 2: replay deque, then the normal lane
        while got < max {
            let s = self.state.load(Ordering::Acquire);
            if ((s & COUNT_MASK) as usize) <= got {
                break; // nothing queued beyond what we already took
            }
            // SAFETY: consumer-side contract — exclusive access to `replay`.
            if let Some(e) = self.replay.with_mut(|r| unsafe { (*r).pop_front() }) {
                out.push(e);
                got += 1;
                continue;
            }
            if let Some(e) = self.normal.pop() {
                out.push(e);
                got += 1;
                continue;
            }
            // count > got but nothing visible here: either an ordinary
            // producer is mid-push (resolves in a few cycles) or the count
            // belongs to a system message that arrived after phase 1. Spin
            // briefly for the former, then hand back what we have — the
            // caller sees the nonzero count and reschedules.
            if spins >= 128 {
                break;
            }
            spin_backoff(&mut spins);
        }
        if got > 0 {
            self.state.fetch_sub(got as u64, Ordering::AcqRel);
        }
        got
    }

    /// Pop a *system-lane* envelope if one is already linked, else `None`
    /// immediately (no spinning). Consumer-side. Lets the resume loop
    /// preserve system-message overtake across a batched drain: one cheap
    /// pointer load per processed message in the common no-system case.
    pub fn try_dequeue_system(&self) -> Option<Envelope> {
        let e = self.system.pop()?;
        self.state.fetch_sub(1, Ordering::AcqRel);
        Some(e)
    }

    /// Consumer-side: number of envelopes waiting in the replay deque.
    /// `resume` samples this around each dispatch to detect that the
    /// message it just processed unstashed envelopes via a behavior change.
    pub(crate) fn replay_len(&self) -> usize {
        // SAFETY: consumer-side contract — exclusive access to `replay`.
        self.replay.with(|r| unsafe { (*r).len() })
    }

    /// Consumer-side: splice the unprocessed remainder of a drained batch
    /// back into the replay deque at position `at` — after the `at`
    /// envelopes a behavior change just unstashed (the stash contract says
    /// those run first), but ahead of everything older still queued (any
    /// replay leftover beyond the batch size, then the normal lane) — and
    /// re-count the envelopes in the state word.
    pub(crate) fn requeue_remainder(
        &self,
        at: usize,
        rest: impl Iterator<Item = Envelope>,
    ) {
        // SAFETY: consumer-side contract — exclusive access to `replay`.
        let n = self.replay.with_mut(|r| {
            let replay = unsafe { &mut *r };
            // split/extend/append keeps the splice O(at + remainder) instead
            // of the O(at * remainder) of repeated VecDeque::insert
            let mut tail = replay.split_off(at);
            let mut n = 0u64;
            for e in rest {
                replay.push_back(e);
                n += 1;
            }
            replay.append(&mut tail);
            n
        });
        if n > 0 {
            self.state.fetch_add(n, Ordering::SeqCst);
        }
    }

    /// Consumer-side raw pop in priority order, without touching the count.
    fn pop_any(&self) -> Option<Envelope> {
        if let Some(e) = self.system.pop() {
            return Some(e);
        }
        // SAFETY: consumer-side contract — exclusive access to `replay`.
        if let Some(e) = self.replay.with_mut(|r| unsafe { (*r).pop_front() }) {
            return Some(e);
        }
        self.normal.pop()
    }

    pub fn is_empty(&self) -> bool {
        self.state.load(Ordering::Acquire) & COUNT_MASK == 0
    }

    pub fn len(&self) -> usize {
        (self.state.load(Ordering::Acquire) & COUNT_MASK) as usize
    }

    /// Close the mailbox and drain everything still queued (system lane
    /// first, like dequeue). Consumer-side. Producers racing with the close
    /// either land in the drained snapshot or observe `Closed`.
    pub fn close(&self) -> Vec<Envelope> {
        let prev = self.state.fetch_or(CLOSED_BIT, Ordering::SeqCst);
        if prev & CLOSED_BIT != 0 {
            return Vec::new();
        }
        let n = (prev & COUNT_MASK) as usize;
        let mut out = Vec::with_capacity(n);
        let mut spins = 0u32;
        // An announced producer (count incremented, node not yet linked)
        // holds us in this loop for a two-instruction window — unless its
        // thread was preempted or killed mid-enqueue, in which case the spin
        // is unbounded. Producers never block inside the window, so in
        // practice it resolves in a few cycles; surface the pathological
        // case instead of wedging silently (close() runs on a scheduler
        // worker during terminate).
        const STUCK_PRODUCER_SPINS: u32 = 1 << 20;
        while out.len() < n {
            match self.pop_any() {
                Some(e) => out.push(e),
                // an announced producer is mid-push; wait it out
                None => {
                    if spins == STUCK_PRODUCER_SPINS {
                        log::warn!(
                            "mailbox close: {spins} spins waiting for an announced \
                             producer to finish linking its envelope — its thread \
                             was likely preempted for a long time or died mid-push"
                        );
                    }
                    spin_backoff(&mut spins);
                }
            }
        }
        self.state.fetch_sub(n as u64, Ordering::AcqRel);
        out
    }

    pub fn is_closed(&self) -> bool {
        self.state.load(Ordering::Acquire) & CLOSED_BIT != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::message::Message;
    use std::sync::Arc;

    fn env(tag: u32) -> Envelope {
        Envelope::asynchronous(None, Message::new(tag))
    }

    fn tag(e: &Envelope) -> u32 {
        *e.msg.downcast_ref::<u32>().unwrap()
    }

    #[test]
    fn fifo_order() {
        let mb = Mailbox::new();
        assert_eq!(mb.enqueue(env(1), false), EnqueueResult::NeedsSchedule);
        assert_eq!(mb.enqueue(env(2), false), EnqueueResult::Stored);
        assert_eq!(tag(&mb.dequeue().unwrap()), 1);
        assert_eq!(tag(&mb.dequeue().unwrap()), 2);
        assert!(mb.dequeue().is_none());
    }

    #[test]
    fn system_messages_overtake() {
        let mb = Mailbox::new();
        mb.enqueue(env(1), false);
        mb.enqueue(env(99), true);
        assert_eq!(tag(&mb.dequeue().unwrap()), 99);
        assert_eq!(tag(&mb.dequeue().unwrap()), 1);
    }

    #[test]
    fn closed_mailbox_rejects() {
        let mb = Mailbox::new();
        mb.enqueue(env(1), false);
        let drained = mb.close();
        assert_eq!(drained.len(), 1);
        assert_eq!(mb.enqueue(env(2), false), EnqueueResult::Closed);
        assert!(mb.is_closed());
    }

    #[test]
    fn push_front_reorders() {
        let mb = Mailbox::new();
        mb.enqueue(env(2), false);
        mb.push_front(env(1)).unwrap();
        assert_eq!(tag(&mb.dequeue().unwrap()), 1);
        assert_eq!(tag(&mb.dequeue().unwrap()), 2);
    }

    #[test]
    fn push_front_on_closed_returns_envelope() {
        // regression: the seed silently dropped the envelope here
        let mb = Mailbox::new();
        mb.close();
        let rejected = mb.push_front(env(7)).unwrap_err();
        assert_eq!(tag(&rejected), 7);
        assert_eq!(mb.len(), 0);
    }

    #[test]
    fn requeue_remainder_orders_and_counts() {
        let mb = Mailbox::new();
        mb.enqueue(env(10), false); // normal lane
        mb.push_front(env(2)).unwrap(); // pre-existing replay leftover
        mb.push_front(env(1)).unwrap(); // fresh unstash, lands in front
        // splice a batch remainder behind the 1 freshly unstashed envelope
        // but ahead of the older leftover and the normal lane
        mb.requeue_remainder(1, vec![env(5), env(6)].into_iter());
        assert_eq!(mb.len(), 5);
        let order: Vec<u32> =
            std::iter::from_fn(|| mb.dequeue()).map(|e| tag(&e)).collect();
        assert_eq!(order, vec![1, 5, 6, 2, 10]);
    }

    #[test]
    fn batch_dequeue_preserves_order_and_count() {
        let mb = Mailbox::new();
        for i in 0..10 {
            mb.enqueue(env(i), false);
        }
        mb.enqueue(env(100), true); // system overtakes the whole batch
        let mut out = Vec::new();
        assert_eq!(mb.dequeue_batch(5, &mut out), 5);
        let tags: Vec<u32> = out.iter().map(tag).collect();
        assert_eq!(tags, vec![100, 0, 1, 2, 3]);
        assert_eq!(mb.len(), 6);
        out.clear();
        assert_eq!(mb.dequeue_batch(100, &mut out), 6);
        assert!(mb.is_empty());
    }

    #[test]
    fn needs_schedule_fires_once_per_empty_transition() {
        let mb = Mailbox::new();
        assert_eq!(mb.enqueue(env(1), false), EnqueueResult::NeedsSchedule);
        assert_eq!(mb.enqueue(env(2), false), EnqueueResult::Stored);
        mb.dequeue();
        mb.dequeue();
        assert_eq!(mb.enqueue(env(3), false), EnqueueResult::NeedsSchedule);
    }

    #[test]
    fn multi_producer_stress_preserves_per_sender_fifo() {
        let mb = Arc::new(Mailbox::new());
        let producers = 4usize;
        let per = 5_000u32;
        let mut handles = Vec::new();
        for p in 0..producers {
            let mb = mb.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let v = (p as u32) << 16 | i;
                    // a sprinkle of system-lane traffic exercises both lanes
                    mb.enqueue(env(v), i % 97 == 0);
                }
            }));
        }
        let mut last = vec![-1i64; producers];
        let mut sys_seen = 0u32;
        let mut normal_seen = 0u32;
        let total = producers as u32 * per;
        let mut got = 0u32;
        while got < total {
            let Some(e) = mb.dequeue() else {
                std::thread::yield_now();
                continue;
            };
            let v = tag(&e);
            let (p, i) = ((v >> 16) as usize, (v & 0xffff) as i64);
            if i % 97 == 0 {
                sys_seen += 1;
            } else {
                // FIFO must hold within each producer's normal-lane stream
                assert!(i > last[p], "producer {p}: {i} after {}", last[p]);
                last[p] = i;
                normal_seen += 1;
            }
            got += 1;
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sys_seen + normal_seen, total);
        assert!(mb.is_empty());
        assert!(mb.dequeue().is_none());
    }

    #[test]
    fn close_during_concurrent_enqueue_loses_nothing() {
        for _ in 0..25 {
            let mb = Arc::new(Mailbox::new());
            let producers = 3usize;
            let per = 400u32;
            let mut handles = Vec::new();
            for _ in 0..producers {
                let mb = mb.clone();
                handles.push(std::thread::spawn(move || {
                    let mut accepted = 0u32;
                    for i in 0..per {
                        if mb.enqueue(env(i), false) != EnqueueResult::Closed {
                            accepted += 1;
                        }
                    }
                    accepted
                }));
            }
            let mut popped = 0u32;
            for _ in 0..150 {
                if mb.dequeue().is_some() {
                    popped += 1;
                }
            }
            let drained = mb.close().len() as u32;
            let accepted: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(accepted, popped + drained, "envelope lost or duplicated");
        }
    }
}
