//! A CAF-like actor substrate: cooperative scheduling, mailboxes, typed
//! message matching, request/response with promises, monitors/links, and the
//! composition operator the paper builds kernel pipelines on (§3.5).
//!
//! This is the L3 foundation the OpenCL-actor integration (`crate::opencl`)
//! plugs into: OpenCL actors implement the same [`AbstractActor`] interface
//! as every CPU actor, so "from the perspective of the runtime system, an
//! OpenCL actor is not distinguishable from any other actor" (paper §3.6).

pub mod ask;
pub mod behavior;
pub mod blocking;
pub mod cell;
pub mod compose;
pub mod envelope;
pub mod mailbox;
pub mod message;
pub mod monitor;
pub mod registry;
pub mod request;
pub mod scheduler;
pub mod system;
pub mod timer;

pub use ask::{FutureSet, RequestFuture, TypedFuture};
pub use behavior::{no_reply, reply, reply_msg, Behavior, Reply};
pub use blocking::ScopedActor;
pub use cell::{ActorCell, Ctx};
pub use compose::{compose, pipeline};
pub use envelope::{ActorId, Envelope, MessageId};
pub use mailbox::Mailbox;
pub use message::Message;
pub use monitor::{Down, ErrorMsg, Exit, ExitReason};
pub use registry::Registry;
pub use system::{ActorSystem, SpawnOptions, SystemConfig};

use std::sync::Arc;

/// The uniform actor interface: everything addressable — event-based actors,
/// OpenCL actor facades, blocking scoped actors, composed actors, and remote
/// proxies — implements this, which is what makes them interchangeable
/// (design goal "seamless integration", paper §3.1).
pub trait AbstractActor: Send + Sync {
    /// Deliver an envelope to this actor's mailbox.
    fn enqueue(&self, env: Envelope);
    /// Globally unique id within the actor system.
    fn id(&self) -> ActorId;
    /// Register `watcher` to receive a [`Down`] message when this actor
    /// terminates. Fires immediately if already terminated.
    fn attach_monitor(&self, watcher: ActorRef);
    /// Register `peer` for bidirectional exit propagation ([`Exit`]).
    fn attach_link(&self, peer: ActorRef);
    /// Human-readable kind, e.g. "event-based", "opencl", "remote".
    fn kind(&self) -> &'static str {
        "event-based"
    }
}

/// A network-transparent actor handle (CAF's `actor`): cheap to clone,
/// hashable by id, usable as a message payload.
#[derive(Clone)]
pub struct ActorRef(pub Arc<dyn AbstractActor>);

impl ActorRef {
    pub fn new(inner: Arc<dyn AbstractActor>) -> Self {
        ActorRef(inner)
    }

    pub fn id(&self) -> ActorId {
        self.0.id()
    }

    pub fn kind(&self) -> &'static str {
        self.0.kind()
    }

    /// Fire-and-forget send (CAF `send`): no response is expected.
    pub fn send_from(&self, sender: Option<ActorRef>, msg: Message) {
        self.0.enqueue(Envelope {
            sender,
            mid: MessageId::ASYNC,
            msg,
        });
    }

    pub fn enqueue(&self, env: Envelope) {
        self.0.enqueue(env);
    }

    /// Non-blocking request (CAF `request(...).then(...)`, the actix
    /// `Address::send` future idiom): issues `v` as a request and returns a
    /// [`RequestFuture`] that resolves exactly once with the reply, an
    /// error, or a timeout — without parking the calling thread. Works
    /// uniformly for local actors and remote proxies (the future slot rides
    /// as the envelope sender through every existing reply path).
    pub fn ask<T: std::any::Any + Send + Sync>(&self, v: T) -> RequestFuture {
        self.ask_msg(Message::new(v))
    }

    /// Untyped sibling of [`ActorRef::ask`].
    pub fn ask_msg(&self, msg: Message) -> RequestFuture {
        RequestFuture::send(self, msg)
    }

    pub fn monitor_with(&self, watcher: ActorRef) {
        self.0.attach_monitor(watcher);
    }

    pub fn link_with(&self, peer: ActorRef) {
        self.0.attach_link(peer);
    }
}

impl std::fmt::Debug for ActorRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ActorRef(#{} {})", self.id(), self.kind())
    }
}

impl PartialEq for ActorRef {
    fn eq(&self, other: &Self) -> bool {
        self.id() == other.id()
    }
}

impl Eq for ActorRef {}

impl std::hash::Hash for ActorRef {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.id().hash(state)
    }
}
