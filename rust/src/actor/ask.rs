//! Non-blocking request futures: `ActorRef::ask(..)` returns a
//! [`RequestFuture`] that resolves via callback/condvar instead of parking a
//! thread per request (the actix `Address<A>`/`Request` idiom, adapted to the
//! dynamically typed substrate).
//!
//! The future's receiving half is itself an [`AbstractActor`] (a
//! [`FutureSlot`]): `ask` mints a fresh request id and passes the slot as the
//! envelope sender, so every existing reply path — local promises, the remote
//! proxy's pending map, `PendingReaper` timeouts, disconnect `fail_pending`,
//! the broken-promise drop guard — delivers into the future without any new
//! wiring. Resolution is exactly-once by construction: the slot's state
//! machine transitions `Pending -> Done` a single time and ignores every
//! later delivery (late timer fires, duplicate errors after a disconnect).
//!
//! One client thread can hold thousands of requests in flight; the bounded
//! [`FutureSet`] collector gives backpressure so an open loop cannot grow the
//! pending set without limit.

use super::envelope::{ActorId, Envelope, MessageId};
use super::message::Message;
use super::monitor::ErrorMsg;
use super::timer::Timer;
use super::{AbstractActor, ActorRef};
use std::any::Any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Future slot ids live far above spawned-actor and remote-proxy ranges so
/// they never collide with either (proxies start at `1 << 48`).
static NEXT_SLOT_ID: AtomicU64 = AtomicU64::new(1 << 49);

type Hook = Box<dyn FnOnce(&Result<Message, ErrorMsg>) + Send>;

enum State {
    /// Reply not yet arrived; hooks run (in registration order) on resolve.
    Pending { hooks: Vec<Hook> },
    Done(Result<Message, ErrorMsg>),
}

/// The receiving half of a [`RequestFuture`]: an addressable one-shot slot
/// that accepts exactly the correlated response (or an async error such as a
/// deadline fire) and resolves the future exactly once.
pub(crate) struct FutureSlot {
    id: ActorId,
    mid: MessageId,
    state: Mutex<State>,
    resolved_cv: Condvar,
}

impl FutureSlot {
    fn new(mid: MessageId) -> Arc<FutureSlot> {
        Arc::new(FutureSlot {
            id: NEXT_SLOT_ID.fetch_add(1, Ordering::Relaxed),
            mid,
            state: Mutex::new(State::Pending { hooks: Vec::new() }),
            resolved_cv: Condvar::new(),
        })
    }

    /// Exactly-once transition to `Done`. Later calls (late timer fire after
    /// the real reply, duplicate disconnect errors) are ignored.
    fn resolve(&self, r: Result<Message, ErrorMsg>) {
        let hooks = {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            match &mut *st {
                State::Done(_) => return,
                State::Pending { hooks } => {
                    let hooks = std::mem::take(hooks);
                    *st = State::Done(r.clone());
                    hooks
                }
            }
        };
        self.resolved_cv.notify_all();
        // run callbacks outside the lock: a hook may wait on another future
        for h in hooks {
            h(&r);
        }
    }

    fn add_hook(&self, h: Hook) {
        let run_now = {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            match &mut *st {
                State::Pending { hooks } => {
                    hooks.push(h);
                    None
                }
                // already resolved: carry the hook out and run it inline
                // (outside the lock) so it never gets lost
                State::Done(r) => Some((h, r.clone())),
            }
        };
        if let Some((h, r)) = run_now {
            h(&r);
        }
    }

    fn try_result(&self) -> Option<Result<Message, ErrorMsg>> {
        match &*self.state.lock().unwrap_or_else(|p| p.into_inner()) {
            State::Done(r) => Some(r.clone()),
            State::Pending { .. } => None,
        }
    }

    fn wait(&self, timeout: Duration) -> Result<Message, ErrorMsg> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let State::Done(r) = &*st {
                return r.clone();
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(ErrorMsg::new("request timed out (future wait)"));
            }
            let (g, _) = self
                .resolved_cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = g;
        }
    }
}

impl AbstractActor for FutureSlot {
    fn enqueue(&self, env: Envelope) {
        // accept only our correlated response, or an async error (deadline
        // fire from the timer, system-internal failure notification)
        let is_reply = env.mid == self.mid.response_for();
        let is_async_err = env.mid.is_async() && env.msg.is::<ErrorMsg>();
        if !is_reply && !is_async_err {
            return;
        }
        match env.msg.downcast_ref::<ErrorMsg>() {
            Some(e) => self.resolve(Err(e.clone())),
            None => self.resolve(Ok(env.msg)),
        }
    }

    fn id(&self) -> ActorId {
        self.id
    }

    fn attach_monitor(&self, _watcher: ActorRef) {}

    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "future-slot"
    }
}

/// A one-shot, composable handle to an in-flight request.
///
/// Cloning is cheap (the slot is shared); every clone observes the same
/// resolution. Dropping all handles before the reply arrives is safe — the
/// reply (or error) still lands in the slot held alive by the sender chain
/// (pending map / promise) and is discarded there.
#[derive(Clone)]
pub struct RequestFuture {
    slot: Arc<FutureSlot>,
}

impl RequestFuture {
    /// Issue `msg` to `target` as a request and return the future. This is
    /// the non-blocking sibling of `ScopedActor::request`: registration (the
    /// slot becoming addressable as the envelope sender) happens before the
    /// send, so a reply can never race past an unregistered waiter.
    pub fn send(target: &ActorRef, msg: Message) -> RequestFuture {
        let mid = MessageId::fresh_request();
        let slot = FutureSlot::new(mid);
        let sender = ActorRef::new(slot.clone() as Arc<dyn AbstractActor>);
        target.enqueue(Envelope {
            sender: Some(sender),
            mid,
            msg,
        });
        RequestFuture { slot }
    }

    /// True once the future holds a result.
    pub fn is_resolved(&self) -> bool {
        self.slot.try_result().is_some()
    }

    /// Non-blocking poll.
    pub fn try_result(&self) -> Option<Result<Message, ErrorMsg>> {
        self.slot.try_result()
    }

    /// Block the calling thread until resolution (condvar park, not a
    /// spinning poll), up to `timeout`. Composable with `then` hooks — both
    /// observe the same exactly-once resolution.
    pub fn wait(&self, timeout: Duration) -> Result<Message, ErrorMsg> {
        self.slot.wait(timeout)
    }

    /// Register a completion callback. Runs on the delivering thread when
    /// the reply/error arrives, or inline if already resolved. Exactly one
    /// invocation, ever.
    pub fn then<F>(&self, f: F)
    where
        F: FnOnce(&Result<Message, ErrorMsg>) + Send + 'static,
    {
        self.slot.add_hook(Box::new(f));
    }

    /// Typed view of this future: extraction to `R` happens at resolution
    /// observation, mirroring `PendingResponse::receive`.
    pub fn map<R: Any + Clone>(&self) -> TypedFuture<R> {
        TypedFuture {
            inner: self.clone(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Arm a per-request deadline on `timer`: if the reply has not arrived
    /// after `d`, the future resolves with a timeout error. A reply that
    /// arrives later is ignored by the exactly-once guard (and vice versa —
    /// the timer firing after resolution is a no-op).
    pub fn deadline(&self, timer: &Timer, d: Duration) -> &Self {
        let slot_ref = ActorRef::new(self.slot.clone() as Arc<dyn AbstractActor>);
        timer.schedule(
            d,
            slot_ref,
            Message::new(ErrorMsg::new(format!(
                "request timed out after {d:?} (ask deadline)"
            ))),
        );
        self
    }
}

/// Typed wrapper over [`RequestFuture`]; see [`RequestFuture::map`].
pub struct TypedFuture<R> {
    inner: RequestFuture,
    _marker: std::marker::PhantomData<fn() -> R>,
}

impl<R: Any + Clone> TypedFuture<R> {
    pub fn wait(&self, timeout: Duration) -> Result<R, ErrorMsg> {
        let msg = self.inner.wait(timeout)?;
        msg.take::<R>().ok_or_else(|| {
            ErrorMsg::new(format!("response type mismatch: got {}", msg.type_name()))
        })
    }

    pub fn is_resolved(&self) -> bool {
        self.inner.is_resolved()
    }
}

struct SetState {
    outstanding: usize,
    results: Vec<Result<Message, ErrorMsg>>,
}

/// Bounded `join_all`-style collector: at most `bound` unresolved futures
/// are admitted at once (`push` blocks past the bound — backpressure for
/// open-loop issuers), and `join_all` parks until every admitted future has
/// resolved. One client thread + one `FutureSet` drives thousands of
/// requests without a thread per request.
pub struct FutureSet {
    bound: usize,
    state: Arc<(Mutex<SetState>, Condvar)>,
}

impl FutureSet {
    /// `bound` == 0 means unbounded.
    pub fn new(bound: usize) -> FutureSet {
        FutureSet {
            bound,
            state: Arc::new((
                Mutex::new(SetState {
                    outstanding: 0,
                    results: Vec::new(),
                }),
                Condvar::new(),
            )),
        }
    }

    /// Admit `fut` into the set, blocking while `bound` futures are already
    /// unresolved. Returns the number currently outstanding (diagnostics).
    pub fn push(&self, fut: &RequestFuture) -> usize {
        let (m, cv) = &*self.state;
        let mut st = m.lock().unwrap_or_else(|p| p.into_inner());
        while self.bound > 0 && st.outstanding >= self.bound {
            st = cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        st.outstanding += 1;
        let now = st.outstanding;
        drop(st);
        let shared = self.state.clone();
        fut.then(move |r| {
            let (m, cv) = &*shared;
            let mut st = m.lock().unwrap_or_else(|p| p.into_inner());
            st.outstanding -= 1;
            st.results.push(r.clone());
            cv.notify_all();
        });
        now
    }

    /// Number of admitted-but-unresolved futures.
    pub fn outstanding(&self) -> usize {
        self.state.0.lock().unwrap_or_else(|p| p.into_inner()).outstanding
    }

    /// Wait (up to `timeout`) for every admitted future to resolve, then
    /// drain and return the collected results (resolution order). On
    /// timeout, returns whatever resolved so far as `Err` of the whole call
    /// would lose data — so it returns the partial drain; check
    /// `outstanding()` afterwards to detect stragglers.
    pub fn join_all(&self, timeout: Duration) -> Vec<Result<Message, ErrorMsg>> {
        let deadline = Instant::now() + timeout;
        let (m, cv) = &*self.state;
        let mut st = m.lock().unwrap_or_else(|p| p.into_inner());
        while st.outstanding > 0 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = g;
        }
        std::mem::take(&mut st.results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok_msg(v: u32) -> Result<Message, ErrorMsg> {
        Ok(Message::new(v))
    }

    #[test]
    fn resolve_is_exactly_once() {
        let slot = FutureSlot::new(MessageId::fresh_request());
        slot.resolve(ok_msg(1));
        slot.resolve(ok_msg(2));
        let got = slot.try_result().unwrap().unwrap();
        assert_eq!(got.take::<u32>(), Some(1));
    }

    #[test]
    fn hooks_fire_once_even_when_registered_late() {
        let slot = FutureSlot::new(MessageId::fresh_request());
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        slot.add_hook(Box::new(move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        slot.resolve(ok_msg(7));
        // late registration runs inline
        let h2 = hits.clone();
        slot.add_hook(Box::new(move |_| {
            h2.fetch_add(1, Ordering::SeqCst);
        }));
        slot.resolve(Err(ErrorMsg::new("dup"))); // ignored
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn slot_rejects_uncorrelated_mids() {
        let mid = MessageId::fresh_request();
        let slot = FutureSlot::new(mid);
        // a response for some other request must not resolve us
        let other = MessageId::fresh_request();
        slot.enqueue(Envelope {
            sender: None,
            mid: other.response_for(),
            msg: Message::new(1u32),
        });
        assert!(slot.try_result().is_none());
        // async non-error chatter is ignored too
        slot.enqueue(Envelope::asynchronous(None, Message::new(2u32)));
        assert!(slot.try_result().is_none());
        // the correlated reply lands
        slot.enqueue(Envelope {
            sender: None,
            mid: mid.response_for(),
            msg: Message::new(3u32),
        });
        assert_eq!(slot.try_result().unwrap().unwrap().take::<u32>(), Some(3));
    }

    #[test]
    fn wait_times_out_cleanly() {
        let slot = FutureSlot::new(MessageId::fresh_request());
        let err = slot.wait(Duration::from_millis(20)).unwrap_err();
        assert!(err.reason.contains("timed out"));
    }

    #[test]
    fn future_set_bounds_and_joins() {
        let set = FutureSet::new(2);
        let s1 = FutureSlot::new(MessageId::fresh_request());
        let s2 = FutureSlot::new(MessageId::fresh_request());
        set.push(&RequestFuture { slot: s1.clone() });
        set.push(&RequestFuture { slot: s2.clone() });
        assert_eq!(set.outstanding(), 2);
        // third push must block until one resolves
        let s3 = FutureSlot::new(MessageId::fresh_request());
        let set_ref = &set;
        std::thread::scope(|scope| {
            let t = scope.spawn(move || {
                set_ref.push(&RequestFuture { slot: s3 });
            });
            std::thread::sleep(Duration::from_millis(30));
            assert!(!t.is_finished(), "push past the bound must block");
            s1.resolve(ok_msg(1));
            t.join().unwrap(); // lint-ok: test thread join
        });
        s2.resolve(ok_msg(2));
        // one future still outstanding — partial drain then full join
        let partial = set.join_all(Duration::from_millis(20));
        assert_eq!(partial.len(), 2);
        assert_eq!(set.outstanding(), 1);
    }
}
