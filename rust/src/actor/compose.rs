//! Actor composition: `C = B ∘ A` (paper §3.5).
//!
//! "We denote C = B ⊙ A to define an actor C which takes any messages it
//! receives as input of A and uses the result as input for B" — intuitively
//! function composition, `h(x) = f(g(x))`. Realized with a response promise
//! for the original requester plus chained request continuations, exactly
//! like CAF's composed actors. OpenCL kernel pipelines (`opencl::stage`)
//! build on this operator; the placement tier's `PipelineSpawn` keeps the
//! same request-chaining shape but routes whole stage chains as one unit
//! so every hop stays device-resident.

use super::behavior::{Behavior, Reply};
use super::system::ActorSystem;
use super::ActorRef;

/// Compose two actors: the result forwards every message to `inner` and
/// pipes the response through `outer` (i.e. `outer ∘ inner`).
pub fn compose(sys: &ActorSystem, outer: ActorRef, inner: ActorRef) -> ActorRef {
    sys.spawn(move |_ctx| {
        let outer = outer.clone();
        let inner = inner.clone();
        Behavior::new().on_any(move |ctx, msg| {
            let promise = ctx.make_promise();
            let outer = outer.clone();
            ctx.request_msg(&inner, msg.clone()).then(move |ctx, res| {
                match res {
                    Ok(m) => {
                        ctx.request_msg(&outer, m).then(move |_ctx, res2| {
                            promise.deliver_result(res2);
                        });
                    }
                    Err(e) => promise.deliver_err(e),
                }
            });
            Reply::Promised
        })
    })
}

/// Compose a whole pipeline: `stages = [a, b, c]` yields `c ∘ b ∘ a`,
/// i.e. messages flow a → b → c (the paper's
/// `move_elems * count_elems * prepare` reads right-to-left; this helper
/// takes stages in flow order instead, which is less error-prone).
pub fn pipeline(sys: &ActorSystem, stages: &[ActorRef]) -> ActorRef {
    assert!(!stages.is_empty(), "pipeline needs at least one stage");
    let mut it = stages.iter().cloned();
    let first = it.next().unwrap(); // lint-ok: asserted non-empty above
    it.fold(first, |acc, next| compose(sys, next, acc))
}
