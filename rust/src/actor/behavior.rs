//! Message-handler behaviors: CAF's partial-function pattern matching,
//! expressed as an ordered list of typed handlers (the "internal DSL for
//! pattern matching", paper §2.1).

use super::cell::Ctx;
use super::message::Message;
use std::any::Any;

/// What a handler produced for the current message.
pub enum Reply {
    /// Void handler: for requests, a unit response is still sent so that
    /// requester continuations fire (CAF sends an empty message).
    None,
    /// Immediate response payload.
    Msg(Message),
    /// The response will be produced later via a [`ResponsePromise`]
    /// (or was delegated to another actor).
    ///
    /// [`ResponsePromise`]: super::request::ResponsePromise
    Promised,
}

/// Respond with a typed value.
pub fn reply<T: Any + Send + Sync>(v: T) -> Reply {
    Reply::Msg(Message::new(v))
}

/// Respond with an already-built message.
pub fn reply_msg(m: Message) -> Reply {
    Reply::Msg(m)
}

/// Void handler result.
pub fn no_reply() -> Reply {
    Reply::None
}

type Handler = Box<dyn FnMut(&mut Ctx, &Message) -> Option<Reply> + Send>;

/// An ordered set of typed message handlers; the first whose parameter type
/// matches the payload wins. Unmatched messages are stashed until the next
/// behavior change (CAF: "messages that cannot be matched stay in the
/// buffer").
#[derive(Default)]
pub struct Behavior {
    handlers: Vec<Handler>,
}

impl Behavior {
    pub fn new() -> Self {
        Behavior { handlers: Vec::new() }
    }

    /// Add a handler for payload type `T`.
    pub fn on<T, F>(mut self, mut f: F) -> Self
    where
        T: Any + Send + Sync,
        F: FnMut(&mut Ctx, &T) -> Reply + Send + 'static,
    {
        self.handlers.push(Box::new(move |ctx, msg| {
            msg.downcast_ref::<T>().map(|v| f(ctx, v))
        }));
        self
    }

    /// Add a catch-all handler receiving the raw message (used e.g. by the
    /// composition actor, which forwards anything).
    pub fn on_any<F>(mut self, mut f: F) -> Self
    where
        F: FnMut(&mut Ctx, &Message) -> Reply + Send + 'static,
    {
        self.handlers.push(Box::new(move |ctx, msg| Some(f(ctx, msg))));
        self
    }

    /// Number of handlers (diagnostics).
    pub fn len(&self) -> usize {
        self.handlers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handlers.is_empty()
    }

    /// Try all handlers in order; `None` means the message did not match.
    pub(crate) fn invoke(&mut self, ctx: &mut Ctx, msg: &Message) -> Option<Reply> {
        for h in self.handlers.iter_mut() {
            if let Some(r) = h(ctx, msg) {
                return Some(r);
            }
        }
        None
    }
}

impl std::fmt::Debug for Behavior {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Behavior({} handlers)", self.handlers.len())
    }
}
