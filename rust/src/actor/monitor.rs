//! Monitoring and linking: the actor model's fault-tolerance primitives
//! (paper §2.1 — "if an actor dies unexpectedly, the runtime system sends a
//! message to each actor monitoring it").

use super::envelope::ActorId;

/// Why an actor terminated.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExitReason {
    /// Behavior finished or the actor quit voluntarily.
    Normal,
    /// The surrounding system shut down.
    Shutdown,
    /// The actor raised an application error.
    Error(String),
    /// The actor's handler panicked (CAF: unhandled exception).
    Panic(String),
    /// A remote actor became unreachable.
    Unreachable,
}

impl ExitReason {
    pub fn is_normal(&self) -> bool {
        matches!(self, ExitReason::Normal | ExitReason::Shutdown)
    }
}

/// Delivered to monitors when the watched actor terminates (CAF
/// `down_msg`). Travels on the system-priority lane — which is what lets
/// a supervisor (e.g. the placement tier's replica dispatcher) observe a
/// death ahead of the ordinary traffic it would otherwise keep routing at
/// the corpse.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Down {
    pub source: ActorId,
    pub reason: ExitReason,
}

/// Delivered to linked actors when the peer terminates (CAF `exit_msg`).
/// Unless the receiver traps exits, a non-normal reason propagates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Exit {
    pub source: ActorId,
    pub reason: ExitReason,
}

impl Exit {
    /// A synthetic fault (CAF's `send_exit` with an error reason): sent to
    /// an actor that does not trap exits, it terminates the actor as if it
    /// had failed, firing `Down` at its monitors. The fault-injection
    /// tests kill replica facades with this.
    pub fn fault(reason: impl Into<String>) -> Exit {
        Exit {
            source: 0,
            reason: ExitReason::Error(reason.into()),
        }
    }
}

/// Error response delivered when a request cannot be served: target dead,
/// handler failed, promise dropped, or timeout (CAF `error`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ErrorMsg {
    pub reason: String,
}

impl ErrorMsg {
    pub fn new(reason: impl Into<String>) -> Self {
        ErrorMsg {
            reason: reason.into(),
        }
    }
}

/// Internal system message: a request the receiving actor issued timed out.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RequestTimeout {
    pub request_id: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normality() {
        assert!(ExitReason::Normal.is_normal());
        assert!(ExitReason::Shutdown.is_normal());
        assert!(!ExitReason::Error("x".into()).is_normal());
        assert!(!ExitReason::Panic("x".into()).is_normal());
    }

    #[test]
    fn fault_is_a_non_normal_exit() {
        let x = Exit::fault("boom");
        assert!(!x.reason.is_normal(), "a fault must propagate/terminate");
        assert_eq!(x.reason, ExitReason::Error("boom".into()));
        assert_eq!(x.source, 0);
    }
}
