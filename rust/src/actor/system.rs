//! The actor system: configuration, spawning, module loading, lifecycle.

use super::behavior::Behavior;
use super::blocking::ScopedActor;
use super::cell::{ActorCell, Ctx, InitNow};
use super::envelope::{ActorId, Envelope};
use super::message::Message;
use super::registry::Registry;
use super::scheduler::Scheduler;
use super::timer::Timer;
use super::ActorRef;
use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// System configuration (CAF's `actor_system_config`).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Scheduler worker threads (default: available parallelism).
    pub scheduler_threads: usize,
    /// Messages one actor may process per scheduler slice.
    pub throughput: usize,
    /// Cap on stashed (unmatched) messages per actor.
    pub max_stash: usize,
    /// Directory holding the AOT artifacts + manifest for the OpenCL module.
    pub artifacts_dir: String,
    /// Deadline for requests issued through a remote proxy (`net::Node`):
    /// a pending remote request that has not been answered within this
    /// window fails with an [`ErrorMsg`] instead of leaking in the
    /// connection's pending map. Also bounds connection establishment.
    ///
    /// [`ErrorMsg`]: super::monitor::ErrorMsg
    pub remote_actor_timeout: Duration,
    /// Deadline for compiling a kernel program on a device queue
    /// (`Program::build`, OpenCL's `clBuildProgram`). Was a hard-coded
    /// 300 s constant in the OpenCL manager.
    pub build_timeout: Duration,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            scheduler_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            throughput: 25,
            max_stash: 1024,
            artifacts_dir: "artifacts".to_string(),
            remote_actor_timeout: Duration::from_secs(30),
            build_timeout: Duration::from_secs(300),
        }
    }
}

impl SystemConfig {
    pub fn with_threads(mut self, n: usize) -> Self {
        self.scheduler_threads = n;
        self
    }

    pub fn with_remote_timeout(mut self, d: Duration) -> Self {
        self.remote_actor_timeout = d;
        self
    }

    pub fn with_build_timeout(mut self, d: Duration) -> Self {
        self.build_timeout = d;
        self
    }

    pub fn with_artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }
}

/// Spawn-time options (`lazy_init` mirrors the paper's Fig 4 setup: the
/// actor is not scheduled for initialization until its first message).
#[derive(Clone, Debug, Default)]
pub struct SpawnOptions {
    pub lazy_init: bool,
    pub name: Option<String>,
}

impl SpawnOptions {
    pub fn lazy() -> Self {
        SpawnOptions {
            lazy_init: true,
            name: None,
        }
    }

    pub fn named(name: impl Into<String>) -> Self {
        SpawnOptions {
            lazy_init: false,
            name: Some(name.into()),
        }
    }
}

struct SystemCore {
    config: SystemConfig,
    scheduler: Scheduler,
    timer: Timer,
    registry: Registry,
    next_id: AtomicU64,
    alive: AtomicUsize,
    spawned_total: AtomicUsize,
    idle_gate: Mutex<()>,
    idle_cv: Condvar,
    /// Loadable modules (e.g. the OpenCL manager) keyed by name —
    /// keeps `actor` decoupled from `opencl` at the type level.
    modules: Mutex<HashMap<&'static str, Arc<dyn Any + Send + Sync>>>,
}

/// Cheaply clonable handle to the runtime (CAF's `actor_system`).
#[derive(Clone)]
pub struct ActorSystem {
    core: Arc<SystemCore>,
}

impl ActorSystem {
    pub fn new(config: SystemConfig) -> ActorSystem {
        let scheduler = Scheduler::new(config.scheduler_threads, config.throughput);
        ActorSystem {
            core: Arc::new(SystemCore {
                scheduler,
                timer: Timer::new(),
                registry: Registry::new(),
                next_id: AtomicU64::new(1),
                alive: AtomicUsize::new(0),
                spawned_total: AtomicUsize::new(0),
                idle_gate: Mutex::new(()),
                idle_cv: Condvar::new(),
                modules: Mutex::new(HashMap::new()),
                config,
            }),
        }
    }

    pub fn config(&self) -> &SystemConfig {
        &self.core.config
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.core.scheduler
    }

    pub fn timer(&self) -> &Timer {
        &self.core.timer
    }

    pub fn registry(&self) -> &Registry {
        &self.core.registry
    }

    pub(crate) fn next_actor_id(&self) -> ActorId {
        self.core.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Spawn an event-based actor from an init function producing its
    /// behavior (CAF `spawn`).
    pub fn spawn<F>(&self, init: F) -> ActorRef
    where
        F: FnOnce(&mut Ctx) -> Behavior + Send + 'static,
    {
        self.spawn_opts(init, SpawnOptions::default())
    }

    /// Spawn with options (lazy initialization, registered name).
    pub fn spawn_opts<F>(&self, init: F, opts: SpawnOptions) -> ActorRef
    where
        F: FnOnce(&mut Ctx) -> Behavior + Send + 'static,
    {
        let id = self.next_actor_id();
        self.core.alive.fetch_add(1, Ordering::AcqRel);
        self.core.spawned_total.fetch_add(1, Ordering::Relaxed);
        let cell = ActorCell::create(self.clone(), id, Box::new(init));
        let r = cell.actor_ref();
        if let Some(name) = opts.name {
            self.core.registry.put(name, r.clone());
        }
        if !opts.lazy_init {
            r.enqueue(Envelope::asynchronous(None, Message::new(InitNow)));
        }
        r
    }

    /// Create a blocking actor bound to the calling thread (CAF's
    /// `scoped_actor`) for request/receive interactions from outside the
    /// scheduler.
    pub fn scoped(&self) -> ScopedActor {
        self.core.alive.fetch_add(1, Ordering::AcqRel);
        ScopedActor::new(self.clone(), self.next_actor_id())
    }

    pub(crate) fn actor_terminated(&self, _id: ActorId) {
        let prev = self.core.alive.fetch_sub(1, Ordering::AcqRel);
        if prev == 1 {
            let _g = self.core.idle_gate.lock().unwrap_or_else(|p| p.into_inner());
            self.core.idle_cv.notify_all();
        }
    }

    /// Number of live actors.
    pub fn alive(&self) -> usize {
        self.core.alive.load(Ordering::Acquire)
    }

    /// Total actors ever spawned (metrics, Fig 4).
    pub fn spawned_total(&self) -> usize {
        self.core.spawned_total.load(Ordering::Relaxed)
    }

    /// Block until every actor terminated (CAF `await_all_actors_done`).
    pub fn await_all_actors_done(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.core.idle_gate.lock().unwrap_or_else(|p| p.into_inner());
        while self.core.alive.load(Ordering::Acquire) > 0 {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g2, _) = self
                .core
                .idle_cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            g = g2;
        }
        true
    }

    /// Register a named module (e.g. the OpenCL manager).
    pub fn put_module(&self, name: &'static str, module: Arc<dyn Any + Send + Sync>) {
        self.core.modules.lock().unwrap_or_else(|p| p.into_inner()).insert(name, module);
    }

    pub fn get_module<T: Any + Send + Sync>(&self, name: &'static str) -> Option<Arc<T>> {
        self.core
            .modules
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(name)
            .cloned()
            .and_then(|m| m.downcast::<T>().ok())
    }

    /// Stop the runtime: clears the registry and modules, halts timer and
    /// scheduler. Actors still queued are dropped.
    pub fn shutdown(&self) {
        self.core.registry.clear();
        self.core.modules.lock().unwrap_or_else(|p| p.into_inner()).clear();
        self.core.timer.shutdown();
        self.core.scheduler.shutdown();
    }
}

impl std::fmt::Debug for ActorSystem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ActorSystem(alive={}, workers={})",
            self.alive(),
            self.core.scheduler.n_workers()
        )
    }
}
