//! Message envelopes and request/response correlation ids.

use super::message::Message;
use super::ActorRef;
use std::sync::atomic::{AtomicU64, Ordering};

/// Globally unique actor id within one actor system (CAF's `actor_id`).
pub type ActorId = u64;

/// Correlates requests with responses (CAF's `message_id`).
///
/// Bit 63 flags a response; id 0 is the plain asynchronous send. Every
/// `request` draws a fresh id from a process-wide counter, and the matching
/// response carries the same id with the response bit set.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct MessageId(pub u64);

static NEXT_REQUEST: AtomicU64 = AtomicU64::new(1);

const RESPONSE_BIT: u64 = 1 << 63;

impl MessageId {
    /// Plain asynchronous message: no response expected.
    pub const ASYNC: MessageId = MessageId(0);

    /// Draw a fresh request id.
    pub fn fresh_request() -> MessageId {
        MessageId(NEXT_REQUEST.fetch_add(1, Ordering::Relaxed))
    }

    /// The id a response to this request must carry.
    pub fn response_for(self) -> MessageId {
        debug_assert!(self.is_request());
        MessageId(self.0 | RESPONSE_BIT)
    }

    /// The request id a response correlates to.
    pub fn request_of(self) -> u64 {
        self.0 & !RESPONSE_BIT
    }

    pub fn is_async(self) -> bool {
        self.0 == 0
    }

    pub fn is_request(self) -> bool {
        self.0 != 0 && self.0 & RESPONSE_BIT == 0
    }

    pub fn is_response(self) -> bool {
        self.0 & RESPONSE_BIT != 0
    }
}

impl std::fmt::Debug for MessageId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_async() {
            write!(f, "mid:async")
        } else if self.is_response() {
            write!(f, "mid:resp({})", self.request_of())
        } else {
            write!(f, "mid:req({})", self.0)
        }
    }
}

/// A message in flight: payload plus routing metadata.
#[derive(Clone, Debug)]
pub struct Envelope {
    /// Who sent it (None for anonymous/system-internal sends).
    pub sender: Option<ActorRef>,
    /// Correlation id; see [`MessageId`].
    pub mid: MessageId,
    /// The payload.
    pub msg: Message,
}

impl Envelope {
    pub fn asynchronous(sender: Option<ActorRef>, msg: Message) -> Self {
        Envelope {
            sender,
            mid: MessageId::ASYNC,
            msg,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_response_correlation() {
        let r = MessageId::fresh_request();
        assert!(r.is_request());
        assert!(!r.is_response());
        let resp = r.response_for();
        assert!(resp.is_response());
        assert_eq!(resp.request_of(), r.0);
    }

    #[test]
    fn ids_are_unique() {
        let a = MessageId::fresh_request();
        let b = MessageId::fresh_request();
        assert_ne!(a.0, b.0);
    }

    #[test]
    fn async_is_neither_request_nor_response() {
        assert!(MessageId::ASYNC.is_async());
        assert!(!MessageId::ASYNC.is_request());
        assert!(!MessageId::ASYNC.is_response());
    }
}
