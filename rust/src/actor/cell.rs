//! The event-based actor: mailbox-driven lifecycle, handler dispatch,
//! behavior changes, continuations, monitors/links, panic isolation.

use super::behavior::{Behavior, Reply};
use super::envelope::{ActorId, Envelope, MessageId};
use super::mailbox::{EnqueueResult, Mailbox};
use super::message::{Message, UnitReply};
use super::monitor::{Down, ErrorMsg, Exit, ExitReason, RequestTimeout};
use super::request::{Continuation, RequestBuilder, ResponsePromise};
use super::system::ActorSystem;
use super::{AbstractActor, ActorRef};
use std::any::Any;
use std::collections::HashMap;
use crate::loom_types::{fence, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::time::Duration;

/// Lock helper that survives mutex poisoning (a panicking handler must not
/// wedge the whole actor system — CAF likewise contains actor failures).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

const IDLE: u8 = 0;
const SCHEDULED: u8 = 1;
const RUNNING: u8 = 2;
const CLOSED: u8 = 3;

/// Outcome of one scheduler slice.
pub enum ResumeResult {
    /// Mailbox drained (or actor terminated); do not requeue.
    Done,
    /// Throughput exhausted with messages left; requeue.
    Reschedule,
}

type InitFn = Box<dyn FnOnce(&mut Ctx) -> Behavior + Send>;

pub(crate) struct CellInner {
    behavior: Option<Behavior>,
    init: Option<InitFn>,
    continuations: HashMap<u64, Continuation>,
    stash: Vec<Envelope>,
    trap_exit: bool,
}

/// The state block of an event-based actor (CAF's `actor_cell` / the
/// scheduling unit of the cooperative scheduler).
pub struct ActorCell {
    id: ActorId,
    system: ActorSystem,
    state: AtomicU8,
    mailbox: Mailbox,
    inner: Mutex<CellInner>,
    watchers: Mutex<Vec<ActorRef>>,
    links: Mutex<Vec<ActorRef>>,
    exit_reason: Mutex<Option<ExitReason>>,
    self_weak: Weak<ActorCell>,
}

/// Marker that triggers eager initialization right after spawn (the default;
/// `lazy_init` skips it, matching the paper's Fig 4 setup).
#[derive(Clone, Copy, Debug)]
pub(crate) struct InitNow;

impl ActorCell {
    pub(crate) fn create(system: ActorSystem, id: ActorId, init: InitFn) -> Arc<ActorCell> {
        Arc::new_cyclic(|weak| ActorCell {
            id,
            system,
            state: AtomicU8::new(IDLE),
            mailbox: Mailbox::new(),
            inner: Mutex::new(CellInner {
                behavior: None,
                init: Some(init),
                continuations: HashMap::new(),
                stash: Vec::new(),
                trap_exit: false,
            }),
            watchers: Mutex::new(Vec::new()),
            links: Mutex::new(Vec::new()),
            exit_reason: Mutex::new(None),
            self_weak: weak.clone(),
        })
    }

    pub fn actor_ref(self: &Arc<Self>) -> ActorRef {
        ActorRef::new(self.clone() as Arc<dyn AbstractActor>)
    }

    fn self_ref(&self) -> Option<ActorRef> {
        self.self_weak
            .upgrade()
            .map(|c| ActorRef::new(c as Arc<dyn AbstractActor>))
    }

    fn schedule(self: &Arc<Self>) {
        // SeqCst pairs with resume's IDLE-store → fence → recheck exit: the
        // caller's mailbox count fetch_add (SeqCst) and this CAS are both in
        // the single total order, so either this CAS observes IDLE or the
        // consumer's post-fence recheck observes the new count — the
        // "neither side schedules" lost-wakeup interleaving cannot occur.
        // With the previous AcqRel CAS, StoreLoad reordering on the consumer
        // could stall the actor permanently with queued messages.
        if self
            .state
            .compare_exchange(IDLE, SCHEDULED, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            self.system.scheduler().submit(self.clone());
        }
    }

    /// Run up to `throughput` messages; called by a scheduler worker.
    ///
    /// Messages are drained from the mailbox in one batch (a single state
    /// transition on the lock-free mailbox) into `batch`, a worker-owned
    /// reusable buffer — no per-slice allocation. System messages arriving
    /// mid-batch still overtake the snapshot's ordinary messages (one cheap
    /// `try_dequeue_system` probe per processed ordinary message — never
    /// over the snapshot's own system messages, which are older), and if
    /// the actor terminates mid-batch the not-yet-processed remainder is
    /// bounced
    /// exactly like `Mailbox::close` bounces queued requests. A behavior
    /// change that replays stashed envelopes ends the slice early: the rest
    /// of the batch is spliced back behind the replayed envelopes so
    /// stash-replay ordering matches the seed's per-message dequeue.
    pub(crate) fn resume(
        self: &Arc<Self>,
        throughput: usize,
        batch: &mut Vec<Envelope>,
    ) -> ResumeResult {
        if self
            .state
            .compare_exchange(SCHEDULED, RUNNING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return ResumeResult::Done; // already closed
        }
        batch.clear();
        self.mailbox.dequeue_batch(throughput, batch);
        // Replay envelopes left over from before this slice (rare: only
        // when the replay deque outgrew `throughput`). Any growth past this
        // base during the slice is a fresh unstash from a behavior change;
        // those replayed envelopes must run before the rest of this drained
        // batch (the seed's per-message dequeue gave stash replay that
        // ordering for free), so the first *ordinary* remainder envelope
        // triggers a splice-back and ends the slice. System envelopes keep
        // processing first — system priority also beats replayed traffic —
        // which preserves system-lane FIFO instead of demoting snapshot
        // system messages into the replay deque.
        let replay_base = self.mailbox.replay_len();
        let mut it = batch.drain(..);
        while let Some(env) = it.next() {
            let ordinary = !is_system_payload(&env.msg);
            if ordinary {
                let at = self.fresh_unstash(replay_base);
                if at > 0 {
                    // a system message earlier in this batch unstashed
                    // envelopes (deferred splice, see below); `env` and the
                    // rest of the batch run after them
                    return self.requeue_and_reschedule(at, std::iter::once(env).chain(it));
                }
                // System-priority overtake across the batch snapshot.
                // Skipped while `env` itself is a system message: the
                // snapshot's system envelopes are older than anything still
                // in the lane — probing there would process younger system
                // messages first and break the system lane's FIFO order.
                while let Some(sys) = self.mailbox.try_dequeue_system() {
                    self.process_guarded(sys);
                    if self.state.load(Ordering::Acquire) == CLOSED {
                        // `env` was drained but not processed: it is part
                        // of the remainder and must be bounced too
                        return self.bounce_remainder(std::iter::once(env).chain(it));
                    }
                    let at = self.fresh_unstash(replay_base);
                    if at > 0 {
                        return self.requeue_and_reschedule(at, std::iter::once(env).chain(it));
                    }
                }
            }
            self.process_guarded(env);
            if self.state.load(Ordering::Acquire) == CLOSED {
                return self.bounce_remainder(it);
            }
            let at = self.fresh_unstash(replay_base);
            if at > 0 && ordinary {
                // A behavior change just replayed stashed envelopes, which
                // must run before anything that arrived after them —
                // including the rest of this drained batch (the seed's
                // per-message dequeue got that ordering for free). `env`
                // was ordinary, so the remainder is all ordinary: splice it
                // behind the replayed envelopes and end the slice. When a
                // *system* message unstashes instead, the splice is
                // deferred: the snapshot's remaining system envelopes
                // outrank replayed traffic and keep processing; the first
                // ordinary envelope splices at the top of the loop.
                return self.requeue_and_reschedule(at, it);
            }
        }
        drop(it);
        // leave RUNNING: either back to IDLE (and re-check for races with
        // concurrent enqueues) or straight to SCHEDULED when work remains.
        if self.mailbox.is_empty() {
            self.state.store(IDLE, Ordering::Release);
            // Dekker handshake with concurrent enqueuers, mirroring
            // worker_loop's announce → fence → re-check park protocol:
            // without this fence the IDLE store can sit in the store buffer
            // while the recheck below reads a stale count of 0, while a
            // sender's CAS in schedule() still reads RUNNING — neither side
            // schedules, and every later enqueue sees a nonzero count
            // (Stored) and never schedules either.
            // pairs with: cell.rs::schedule (the sender's SeqCst CAS)
            fence(Ordering::SeqCst);
            if !self.mailbox.is_empty() {
                self.schedule();
            }
            ResumeResult::Done
        } else {
            self.state.store(SCHEDULED, Ordering::Release);
            ResumeResult::Reschedule
        }
    }

    /// Process one envelope with panic isolation (a panicking handler
    /// terminates the actor, not the worker).
    fn process_guarded(self: &Arc<Self>, env: Envelope) {
        let me = self.clone();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            me.process(env);
        }));
        if let Err(p) = res {
            let what = panic_to_string(&p);
            self.terminate(ExitReason::Panic(what));
        }
    }

    /// Envelopes unstashed since the slice began (`base` = the replay-deque
    /// length sampled right after the batch drain).
    fn fresh_unstash(&self, base: usize) -> usize {
        self.mailbox.replay_len().saturating_sub(base)
    }

    /// Splice the unprocessed batch remainder behind the `at` freshly
    /// replayed envelopes and hand the slice back to the scheduler.
    fn requeue_and_reschedule(
        self: &Arc<Self>,
        at: usize,
        rest: impl Iterator<Item = Envelope>,
    ) -> ResumeResult {
        self.mailbox.requeue_remainder(at, rest);
        self.state.store(SCHEDULED, Ordering::Release);
        ResumeResult::Reschedule
    }

    /// The actor died mid-batch: dead-letter the rest of the drained
    /// snapshot so requesters get an error instead of silence.
    fn bounce_remainder(
        self: &Arc<Self>,
        it: impl Iterator<Item = Envelope>,
    ) -> ResumeResult {
        let me_ref = self.self_ref();
        for rest in it {
            respond(
                &rest.sender,
                rest.mid,
                me_ref.clone(),
                Message::new(ErrorMsg::new("actor terminated")),
            );
        }
        ResumeResult::Done
    }

    fn process(self: &Arc<Self>, env: Envelope) {
        let Envelope { sender, mid, msg } = env;
        let mut guard = lock(&self.inner);

        // lazy/eager initialization: build the behavior on first dispatch
        if let Some(init) = guard.init.take() {
            let mut ctx = Ctx::new(self, None, MessageId::ASYNC, &mut guard);
            let behavior = init(&mut ctx);
            let (become_next, exit) = ctx.finish();
            guard.behavior = Some(become_next.unwrap_or(behavior));
            if let Some(reason) = exit {
                drop(guard);
                self.terminate(reason);
                return;
            }
        }
        if msg.is::<InitNow>() {
            return; // init already ran above
        }

        // responses resolve pending continuations
        if mid.is_response() {
            let cont = guard.continuations.remove(&mid.request_of());
            if let Some(cont) = cont {
                let result = match msg.downcast_ref::<ErrorMsg>() {
                    Some(e) => Err(e.clone()),
                    None => Ok(msg),
                };
                let mut ctx = Ctx::new(self, sender, MessageId::ASYNC, &mut guard);
                cont(&mut ctx, result);
                let (become_next, exit) = ctx.finish();
                self.apply_transitions(guard, become_next, exit);
            }
            return;
        }

        // request timeouts fire the continuation with an error
        if let Some(t) = msg.downcast_ref::<RequestTimeout>() {
            if let Some(cont) = guard.continuations.remove(&t.request_id) {
                let mut ctx = Ctx::new(self, sender, MessageId::ASYNC, &mut guard);
                cont(&mut ctx, Err(ErrorMsg::new("request timed out")));
                let (become_next, exit) = ctx.finish();
                self.apply_transitions(guard, become_next, exit);
            }
            return;
        }

        // exit propagation (links)
        if let Some(x) = msg.downcast_ref::<Exit>() {
            if !guard.trap_exit && !x.reason.is_normal() {
                drop(guard);
                self.terminate(x.reason.clone());
                return;
            }
            // trapped: fall through to the behavior like a normal message
        }

        // ordinary dispatch
        let mut behavior = guard.behavior.take();
        let mut ctx = Ctx::new(self, sender.clone(), mid, &mut guard);
        let outcome = behavior.as_mut().and_then(|b| b.invoke(&mut ctx, &msg));
        let promised = ctx.promised;
        let (become_next, exit) = ctx.finish();
        match outcome {
            Some(Reply::Msg(m)) => respond(&sender, mid, self.self_ref(), m),
            Some(Reply::None) => {
                if !promised {
                    respond(&sender, mid, self.self_ref(), Message::new(UnitReply));
                }
            }
            Some(Reply::Promised) => {}
            None => {
                // unmatched: system messages are dropped, ordinary traffic is
                // stashed until the next behavior change (CAF semantics)
                if !is_system_payload(&msg) {
                    if guard.stash.len() < self.system.config().max_stash {
                        guard.stash.push(Envelope { sender, mid, msg });
                    } else if mid.is_request() {
                        respond(
                            &sender,
                            mid,
                            self.self_ref(),
                            Message::new(ErrorMsg::new("unexpected message (stash full)")),
                        );
                    }
                }
            }
        }
        // restore or replace behavior, then drain the stash on change
        let changed = become_next.is_some();
        guard.behavior = become_next.or(behavior);
        if changed {
            let stash = std::mem::take(&mut guard.stash);
            for e in stash.into_iter().rev() {
                self.unstash(e);
            }
        }
        self.apply_transitions(guard, None, exit);
    }

    /// Replay one stashed envelope at the front of the mailbox; if the
    /// mailbox closed meanwhile, route it to dead-letters like `close()`
    /// does (the seed silently dropped it).
    fn unstash(self: &Arc<Self>, env: Envelope) {
        if let Err(env) = self.mailbox.push_front(env) {
            respond(
                &env.sender,
                env.mid,
                self.self_ref(),
                Message::new(ErrorMsg::new("actor terminated")),
            );
        }
    }

    fn apply_transitions(
        self: &Arc<Self>,
        mut guard: MutexGuard<'_, CellInner>,
        become_next: Option<Behavior>,
        exit: Option<ExitReason>,
    ) {
        if let Some(b) = become_next {
            guard.behavior = Some(b);
            let stash = std::mem::take(&mut guard.stash);
            for e in stash.into_iter().rev() {
                self.unstash(e);
            }
        }
        drop(guard);
        if let Some(reason) = exit {
            self.terminate(reason);
        }
    }

    /// Terminate: close the mailbox, bounce pending requests, notify
    /// monitors and links, release the system bookkeeping.
    pub(crate) fn terminate(self: &Arc<Self>, reason: ExitReason) {
        let prev = self.state.swap(CLOSED, Ordering::AcqRel);
        if prev == CLOSED {
            return;
        }
        *lock(&self.exit_reason) = Some(reason.clone());
        let drained = self.mailbox.close();
        let me = self.self_ref();
        for env in drained {
            if env.mid.is_request() {
                respond(
                    &env.sender,
                    env.mid,
                    me.clone(),
                    Message::new(ErrorMsg::new("actor terminated")),
                );
            }
        }
        {
            let mut inner = lock(&self.inner);
            inner.behavior = None;
            inner.init = None;
            inner.continuations.clear();
            inner.stash.clear();
        }
        let down = Message::new(Down {
            source: self.id,
            reason: reason.clone(),
        });
        for w in lock(&self.watchers).drain(..) {
            w.enqueue(Envelope::asynchronous(me.clone(), down.clone()));
        }
        let exit = Message::new(Exit {
            source: self.id,
            reason,
        });
        for l in lock(&self.links).drain(..) {
            l.enqueue(Envelope::asynchronous(me.clone(), exit.clone()));
        }
        self.system.actor_terminated(self.id);
    }

    pub fn is_terminated(&self) -> bool {
        self.state.load(Ordering::Acquire) == CLOSED
    }
}

fn respond(sender: &Option<ActorRef>, mid: MessageId, me: Option<ActorRef>, m: Message) {
    if mid.is_request() {
        if let Some(s) = sender {
            s.enqueue(Envelope {
                sender: me,
                mid: mid.response_for(),
                msg: m,
            });
        }
    }
}

pub(crate) fn is_system_payload(msg: &Message) -> bool {
    msg.is::<Down>() || msg.is::<Exit>() || msg.is::<RequestTimeout>() || msg.is::<InitNow>()
}

fn panic_to_string(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic".to_string()
    }
}

impl AbstractActor for ActorCell {
    fn enqueue(&self, env: Envelope) {
        let system_lane = is_system_payload(&env.msg);
        let sender = env.sender.clone();
        let mid = env.mid;
        match self.mailbox.enqueue(env, system_lane) {
            EnqueueResult::NeedsSchedule => {
                if let Some(me) = self.self_weak.upgrade() {
                    me.schedule();
                }
            }
            EnqueueResult::Stored => {}
            EnqueueResult::Closed => {
                respond(
                    &sender,
                    mid,
                    self.self_ref(),
                    Message::new(ErrorMsg::new("actor terminated")),
                );
            }
        }
    }

    fn id(&self) -> ActorId {
        self.id
    }

    fn attach_monitor(&self, watcher: ActorRef) {
        if self.is_terminated() {
            let reason = lock(&self.exit_reason)
                .clone()
                .unwrap_or(ExitReason::Normal);
            watcher.enqueue(Envelope::asynchronous(
                self.self_ref(),
                Message::new(Down {
                    source: self.id,
                    reason,
                }),
            ));
        } else {
            lock(&self.watchers).push(watcher);
        }
    }

    fn attach_link(&self, peer: ActorRef) {
        if self.is_terminated() {
            let reason = lock(&self.exit_reason)
                .clone()
                .unwrap_or(ExitReason::Normal);
            peer.enqueue(Envelope::asynchronous(
                self.self_ref(),
                Message::new(Exit {
                    source: self.id,
                    reason,
                }),
            ));
        } else {
            lock(&self.links).push(peer);
        }
    }
}

// ---------------------------------------------------------------------------
// Ctx — the handler-visible actor context
// ---------------------------------------------------------------------------

/// What a running handler sees of its actor (CAF's `self` pointer): send,
/// request, promise, delegate, behavior change, spawn, quit.
pub struct Ctx<'a> {
    cell: &'a Arc<ActorCell>,
    sender: Option<ActorRef>,
    mid: MessageId,
    inner: &'a mut CellInner,
    become_next: Option<Behavior>,
    exit: Option<ExitReason>,
    pub(crate) promised: bool,
}

impl<'a> Ctx<'a> {
    fn new(
        cell: &'a Arc<ActorCell>,
        sender: Option<ActorRef>,
        mid: MessageId,
        guard: &'a mut MutexGuard<'_, CellInner>,
    ) -> Ctx<'a> {
        // reborrow the guard's target for the context lifetime
        let inner: &'a mut CellInner = &mut **guard;
        Ctx {
            cell,
            sender,
            mid,
            inner,
            become_next: None,
            exit: None,
            promised: false,
        }
    }

    fn finish(self) -> (Option<Behavior>, Option<ExitReason>) {
        (self.become_next, self.exit)
    }

    /// Handle to the running actor itself.
    pub fn me(&self) -> ActorRef {
        self.cell.actor_ref()
    }

    pub fn id(&self) -> ActorId {
        self.cell.id
    }

    pub fn system(&self) -> &ActorSystem {
        &self.cell.system
    }

    /// Sender of the message being processed.
    pub fn sender(&self) -> Option<&ActorRef> {
        self.sender.as_ref()
    }

    /// Correlation id of the message being processed.
    pub fn message_id(&self) -> MessageId {
        self.mid
    }

    /// Fire-and-forget send with `self` as sender.
    pub fn send<T: Any + Send + Sync>(&self, target: &ActorRef, v: T) {
        self.send_msg(target, Message::new(v));
    }

    pub fn send_msg(&self, target: &ActorRef, m: Message) {
        target.enqueue(Envelope::asynchronous(Some(self.me()), m));
    }

    /// Issue a request; register the response continuation via
    /// [`RequestBuilder::then`].
    pub fn request<T: Any + Send + Sync>(
        &mut self,
        target: &ActorRef,
        v: T,
    ) -> RequestBuilder<'_, 'a> {
        self.request_msg(target, Message::new(v))
    }

    pub fn request_msg(&mut self, target: &ActorRef, m: Message) -> RequestBuilder<'_, 'a> {
        let mid = MessageId::fresh_request();
        target.enqueue(Envelope {
            sender: Some(self.me()),
            mid,
            msg: m,
        });
        RequestBuilder {
            rid: mid.0,
            ctx: self,
        }
    }

    pub(crate) fn store_continuation(&mut self, rid: u64, cont: Continuation) {
        self.inner.continuations.insert(rid, cont);
    }

    pub(crate) fn arm_request_timeout(&mut self, rid: u64, d: Duration) {
        let me = self.me();
        self.system().timer().schedule(
            d,
            me,
            Message::new(RequestTimeout { request_id: rid }),
        );
    }

    /// Capture the current request for a deferred reply (CAF
    /// `make_response_promise`). The handler should return
    /// [`Reply::Promised`].
    pub fn make_promise(&mut self) -> ResponsePromise {
        self.promised = true;
        ResponsePromise::new(self.sender.clone(), self.mid, Some(self.me()))
    }

    /// Forward the current request to `target`, which becomes responsible
    /// for replying to the original requester (CAF delegation — the
    /// composition primitive, §3.5).
    pub fn delegate(&mut self, target: &ActorRef, m: Message) {
        self.promised = true;
        target.enqueue(Envelope {
            sender: self.sender.clone(),
            mid: self.mid,
            msg: m,
        });
    }

    /// Replace the behavior after this handler returns; stashed messages
    /// are replayed.
    pub fn become_(&mut self, b: Behavior) {
        self.become_next = Some(b);
    }

    /// Receive `Exit` messages as ordinary messages instead of dying.
    pub fn trap_exit(&mut self, on: bool) {
        self.inner.trap_exit = on;
    }

    /// Monitor `who`: a [`Down`] message arrives when it terminates.
    pub fn monitor(&self, who: &ActorRef) {
        who.monitor_with(self.me());
    }

    /// Link with `who`: exits propagate in both directions.
    pub fn link_to(&self, who: &ActorRef) {
        who.link_with(self.me());
        self.cell_links_push(who.clone());
    }

    fn cell_links_push(&self, peer: ActorRef) {
        lock(&self.cell.links).push(peer);
    }

    /// Terminate after this handler returns.
    pub fn quit(&mut self, reason: ExitReason) {
        self.exit = Some(reason);
    }

    /// Spawn a child actor (same as `system().spawn`).
    pub fn spawn<F>(&self, init: F) -> ActorRef
    where
        F: FnOnce(&mut Ctx) -> Behavior + Send + 'static,
    {
        self.system().spawn(init)
    }
}
