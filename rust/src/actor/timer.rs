//! Delayed message delivery: request timeouts, delayed sends, simulated
//! device latencies (the `sim` profiles schedule completion padding here).

use super::envelope::Envelope;
use super::message::Message;
use super::ActorRef;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct Entry {
    at: Instant,
    seq: u64,
    target: ActorRef,
    msg: Message,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

#[derive(Default)]
struct State {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
    shutdown: bool,
}

/// A single timer thread ordered by deadline (CAF's clock actor).
pub struct Timer {
    state: Arc<(Mutex<State>, Condvar)>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Timer {
        let state: Arc<(Mutex<State>, Condvar)> = Arc::new((Mutex::new(State::default()), Condvar::new()));
        let st = state.clone();
        let worker = std::thread::Builder::new()
            .name("caf-timer".into())
            .spawn(move || timer_loop(st))
            .expect("spawn timer thread"); // lint-ok: fail-fast at system startup
        Timer {
            state,
            worker: Mutex::new(Some(worker)),
        }
    }

    /// Deliver `msg` to `target` after `delay`.
    pub fn schedule(&self, delay: Duration, target: ActorRef, msg: Message) {
        let (m, cv) = &*self.state;
        let mut st = m.lock().unwrap_or_else(|p| p.into_inner());
        st.seq += 1;
        let seq = st.seq;
        st.heap.push(Reverse(Entry {
            at: Instant::now() + delay,
            seq,
            target,
            msg,
        }));
        cv.notify_one();
    }

    /// Number of pending timers (diagnostics).
    pub fn pending(&self) -> usize {
        self.state.0.lock().unwrap_or_else(|p| p.into_inner()).heap.len()
    }

    pub fn shutdown(&self) {
        {
            let (m, cv) = &*self.state;
            let mut st = m.lock().unwrap_or_else(|p| p.into_inner());
            st.shutdown = true;
            st.heap.clear();
            cv.notify_all();
        }
        if let Some(w) = self.worker.lock().unwrap_or_else(|p| p.into_inner()).take() {
            let _ = w.join();
        }
    }
}

fn timer_loop(state: Arc<(Mutex<State>, Condvar)>) {
    let (m, cv) = &*state;
    let mut st = m.lock().unwrap_or_else(|p| p.into_inner());
    loop {
        if st.shutdown {
            return;
        }
        let now = Instant::now();
        // fire everything due
        while let Some(Reverse(top)) = st.heap.peek() {
            if top.at > now {
                break;
            }
            let Reverse(e) = st.heap.pop().unwrap(); // lint-ok: loop guard checked heap non-empty
            // deliver outside the lock to avoid holding it across enqueue
            drop(st);
            e.target
                .enqueue(Envelope::asynchronous(None, e.msg));
            st = m.lock().unwrap_or_else(|p| p.into_inner());
            if st.shutdown {
                return;
            }
        }
        let wait = st
            .heap
            .peek()
            .map(|Reverse(e)| e.at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        let (g, _) = cv.wait_timeout(st, wait).unwrap_or_else(|p| p.into_inner());
        st = g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::envelope::{ActorId, Envelope};
    use crate::actor::AbstractActor;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct Probe {
        hits: AtomicUsize,
    }
    impl AbstractActor for Probe {
        fn enqueue(&self, _env: Envelope) {
            self.hits.fetch_add(1, Ordering::SeqCst);
        }
        fn id(&self) -> ActorId {
            999
        }
        fn attach_monitor(&self, _w: ActorRef) {}
        fn attach_link(&self, _p: ActorRef) {}
    }

    #[test]
    fn fires_in_order_and_shutdown_is_clean() {
        let t = Timer::new();
        let probe = Arc::new(Probe {
            hits: AtomicUsize::new(0),
        });
        let r = ActorRef::new(probe.clone());
        t.schedule(Duration::from_millis(5), r.clone(), Message::new(1u32));
        t.schedule(Duration::from_millis(10), r.clone(), Message::new(2u32));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(probe.hits.load(Ordering::SeqCst), 2);
        t.shutdown();
    }

    #[test]
    fn pending_counts() {
        let t = Timer::new();
        let probe = Arc::new(Probe {
            hits: AtomicUsize::new(0),
        });
        t.schedule(
            Duration::from_secs(60),
            ActorRef::new(probe),
            Message::new(()),
        );
        assert_eq!(t.pending(), 1);
        t.shutdown();
    }
}
