//! Named actor registry (CAF's actor registry): lookup by name for
//! system-level services and the network layer.

use super::ActorRef;
use std::collections::HashMap;
use std::sync::Mutex;

#[derive(Default)]
pub struct Registry {
    names: Mutex<HashMap<String, ActorRef>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register `who` under `name`, replacing any previous entry.
    pub fn put(&self, name: impl Into<String>, who: ActorRef) {
        self.names.lock().unwrap_or_else(|p| p.into_inner()).insert(name.into(), who);
    }

    pub fn get(&self, name: &str) -> Option<ActorRef> {
        self.names.lock().unwrap_or_else(|p| p.into_inner()).get(name).cloned()
    }

    pub fn remove(&self, name: &str) -> Option<ActorRef> {
        self.names.lock().unwrap_or_else(|p| p.into_inner()).remove(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.names.lock().unwrap_or_else(|p| p.into_inner()).keys().cloned().collect()
    }

    pub fn clear(&self) {
        self.names.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    pub fn len(&self) -> usize {
        self.names.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
