//! Sharded cooperative work-stealing scheduler (CAF §2.1: "actors are
//! implemented as sub-thread entities and run in a cooperative scheduler
//! using work-stealing") — lock-free on the per-message path.
//!
//! Topology:
//!
//! * each worker owns a Chase–Lev deque — local LIFO push/take by the
//!   owner, lock-free FIFO steal (batched, up to `throughput/2` jobs) by
//!   idle victims;
//! * non-worker threads (scoped actors, the timer, device-queue
//!   callbacks) submit to one shared Vyukov MPSC injector. Its single-
//!   consumer side is elected by a CAS claim that is only ever held for
//!   the few instructions of a drain — never across actor code — and the
//!   drain surfaces jobs into the claimant's deque where they are
//!   stealable. Any idle worker can claim, so an external job can never
//!   be pinned behind a busy worker;
//! * a token [`Parker`] per worker.
//!
//! Idle workers park on their token instead of the seed's 10 ms
//! `wait_timeout` poll. The protocol is the classic two-sided handshake:
//! a submitter pushes, issues a SeqCst fence, then checks the sleeper
//! bitmask; a worker sets its sleeper bit, issues a SeqCst fence, re-checks
//! every queue, and only then parks. Whichever side loses the race sees the
//! other's write, so a wakeup can never be lost — the seed's
//! `submit`-reads-`sleepers`-after-push-under-a-different-lock race (and
//! its 10 ms latency floor in `fig4_spawn`/`fig5_overhead`) is gone.

use super::cell::{ActorCell, ResumeResult};
use super::envelope::Envelope;
use crate::concurrent::{spin_backoff, CountedQueue, Parker, Steal, WorkDeque};
use crate::loom_types::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Runnable = Arc<ActorCell>;

/// The sleeper bitmask is one u64 — workers beyond 64 would be
/// unaddressable, so the worker count is clamped.
const MAX_WORKERS: usize = 64;

struct Shard {
    deque: WorkDeque<Runnable>,
    parker: Parker,
}

struct Shared {
    /// Distinguishes schedulers so a worker of system A submitting to
    /// system B cannot mistake B's shard for its own deque.
    id: u64,
    shards: Vec<Shard>,
    /// External submissions; multi-producer lock-free push.
    injector: CountedQueue<Runnable>,
    /// Elects the injector's single consumer (MPSC contract). Held only
    /// inside `find_job` for a bounded drain, never across actor code.
    injector_claim: AtomicBool,
    /// Bit k set <=> worker k is parked (or committing to park).
    sleepers: AtomicU64,
    shutdown: AtomicBool,
    throughput: usize,
    /// total scheduler slices executed (metrics)
    resumes: AtomicUsize,
}

static NEXT_SCHEDULER_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (scheduler id, worker index) of the current thread;
    /// (0, usize::MAX) on non-worker threads.
    static WORKER: std::cell::Cell<(u64, usize)> =
        const { std::cell::Cell::new((0, usize::MAX)) };
}

pub struct Scheduler {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Scheduler {
    pub fn new(n_workers: usize, throughput: usize) -> Scheduler {
        let n = n_workers.clamp(1, MAX_WORKERS);
        let shared = Arc::new(Shared {
            id: NEXT_SCHEDULER_ID.fetch_add(1, Ordering::Relaxed),
            shards: (0..n)
                .map(|_| Shard {
                    deque: WorkDeque::new(),
                    parker: Parker::new(),
                })
                .collect(),
            injector: CountedQueue::new(),
            injector_claim: AtomicBool::new(false),
            sleepers: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            throughput: throughput.max(1),
            resumes: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("caf-worker-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    .expect("spawn scheduler worker") // lint-ok: fail-fast at system startup
            })
            .collect();
        Scheduler {
            shared,
            workers: Mutex::new(workers),
        }
    }

    /// Enqueue an actor for execution. Lock-free: worker threads push onto
    /// their own deque, external threads onto the shared injector.
    pub fn submit(&self, cell: Runnable) {
        let sh = &self.shared;
        let (sid, idx) = WORKER.with(|w| w.get());
        if sid == sh.id && idx < sh.shards.len() {
            // SAFETY: this thread is worker `idx` of this scheduler, the
            // unique owner of that deque.
            unsafe { sh.shards[idx].deque.push(cell) };
        } else {
            // the injector is never closed, so this cannot fail
            let _ = sh.injector.push(cell);
        }
        // pairs with: scheduler.rs::worker_loop (sleepers-announce → fence
        // → work_available recheck park protocol)
        fence(Ordering::SeqCst);
        sh.wake_any();
    }

    pub fn n_workers(&self) -> usize {
        self.shared.shards.len()
    }

    /// Total scheduler slices executed so far (metrics).
    pub fn resume_count(&self) -> usize {
        self.shared.resumes.load(Ordering::Relaxed)
    }

    /// Stop all workers; queued actors are dropped.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for s in &self.shared.shards {
            s.parker.unpark();
        }
        let mut ws = self.workers.lock().unwrap_or_else(|p| p.into_inner());
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

impl Shared {
    /// Wake one parked worker, if any.
    fn wake_any(&self) {
        loop {
            let mask = self.sleepers.load(Ordering::SeqCst);
            if mask == 0 {
                return;
            }
            let k = mask.trailing_zeros() as usize;
            let bit = 1u64 << k;
            if self
                .sleepers
                .compare_exchange(mask, mask & !bit, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                // a racing waker may also unpark k; tokens coalesce, so
                // the worst case is one spurious wake
                self.shards[k].parker.unpark();
                return;
            }
        }
    }
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    WORKER.with(|w| w.set((shared.id, me)));
    let bit = 1u64 << me;
    // reusable per-slice envelope buffer (no per-resume allocation)
    let mut batch: Vec<Envelope> = Vec::with_capacity(shared.throughput);
    let mut idle_spins = 0u32;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        if let Some(cell) = find_job(&shared, me) {
            idle_spins = 0;
            shared.resumes.fetch_add(1, Ordering::Relaxed);
            if let ResumeResult::Reschedule = cell.resume(shared.throughput, &mut batch) {
                // SAFETY: we are worker `me`, the deque owner.
                unsafe { shared.shards[me].deque.push(cell) };
            }
            continue;
        }
        // Park protocol: announce, fence, re-check, then sleep.
        shared.sleepers.fetch_or(bit, Ordering::SeqCst);
        // pairs with: scheduler.rs::submit (push → fence → wake_any)
        fence(Ordering::SeqCst);
        if shared.shutdown.load(Ordering::SeqCst) || work_available(&shared) {
            shared.sleepers.fetch_and(!bit, Ordering::SeqCst);
            // Work is visible but find_job couldn't claim it: a producer
            // mid-push, the injector claim held elsewhere, or contended
            // steals. Back off (yields every 64 spins) instead of looping
            // at full speed — on an oversubscribed host a hot loop here
            // starves the very producer it is waiting for.
            spin_backoff(&mut idle_spins);
            continue;
        }
        shared.shards[me].parker.park();
        // A wake_any-delivered wake cleared our bit before unparking, but
        // park() can also return on a stale banked token (an unpark that
        // raced an earlier round's re-check window). Clear unconditionally:
        // a set bit on a running worker would soak up wake_any's single
        // wake, leaving a genuinely parked worker asleep behind a busy one.
        shared.sleepers.fetch_and(!bit, Ordering::SeqCst);
    }
}

/// Post-announce re-check: anything any worker could run right now?
/// (Injector jobs are claimable by everyone, deque jobs stealable.)
fn work_available(shared: &Shared) -> bool {
    if !shared.injector.is_empty() {
        return true;
    }
    shared.shards.iter().any(|s| !s.deque.is_empty())
}

fn find_job(shared: &Shared, me: usize) -> Option<Runnable> {
    let shard = &shared.shards[me];
    // SAFETY: worker `me` owns this deque.
    if let Some(c) = unsafe { shard.deque.take() } {
        return Some(c);
    }
    // Claim the injector and surface a batch into our deque, where the
    // jobs are stealable; the claim is released before running anything.
    if !shared.injector.is_empty()
        && shared
            .injector_claim
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    {
        let first = shared.injector.pop();
        let mut moved = 0;
        if first.is_some() {
            while moved < shared.throughput {
                match shared.injector.pop() {
                    Some(c) => {
                        // SAFETY: worker `me` owns this deque.
                        unsafe { shard.deque.push(c) };
                        moved += 1;
                    }
                    None => break,
                }
            }
        }
        shared.injector_claim.store(false, Ordering::Release);
        if moved > 0 {
            // several jobs surfaced at once — recruit parked helpers
            // pairs with: scheduler.rs::worker_loop (pre-park recheck)
            fence(Ordering::SeqCst);
            shared.wake_any();
        }
        if first.is_some() {
            return first;
        }
    }
    // Steal: scan victims after ourselves; take one job to run and move a
    // batch of up to throughput/2 - 1 more onto our own deque.
    let n = shared.shards.len();
    for off in 1..n {
        let v = (me + off) % n;
        let victim = &shared.shards[v].deque;
        let mut retries = 0;
        loop {
            match victim.steal() {
                Steal::Success(first) => {
                    let limit = (shared.throughput / 2).saturating_sub(1);
                    let mut extra = 0;
                    while extra < limit {
                        match victim.steal() {
                            Steal::Success(c) => {
                                // SAFETY: worker `me` owns its deque.
                                unsafe { shard.deque.push(c) };
                                extra += 1;
                            }
                            _ => break,
                        }
                    }
                    if extra > 0 {
                        // pairs with: scheduler.rs::worker_loop (pre-park recheck)
                        fence(Ordering::SeqCst);
                        shared.wake_any();
                    }
                    return Some(first);
                }
                Steal::Retry => {
                    retries += 1;
                    if retries > 8 {
                        // contended victim — move on; the pre-park re-check
                        // still sees its deque as non-empty if work remains
                        break;
                    }
                    crate::loom_types::cpu_relax();
                }
                Steal::Empty => break,
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::{no_reply, reply, ActorSystem, Behavior, SystemConfig};
    use std::time::{Duration, Instant};

    #[test]
    fn scheduler_starts_and_stops() {
        let s = Scheduler::new(4, 25);
        assert_eq!(s.n_workers(), 4);
        s.shutdown();
    }

    #[test]
    fn zero_workers_clamped_to_one() {
        let s = Scheduler::new(0, 25);
        assert_eq!(s.n_workers(), 1);
        s.shutdown();
    }

    #[test]
    fn worker_count_clamped_to_bitmask_width() {
        let s = Scheduler::new(1000, 25);
        assert_eq!(s.n_workers(), MAX_WORKERS);
        s.shutdown();
    }

    /// Regression test for the seed's lost-wakeup race: `submit` read
    /// `sleepers` under a separate lock after pushing, so a worker deciding
    /// to sleep between the push and the check missed the notify and only
    /// a 10 ms poll timeout recovered it. The new protocol has **no** poll
    /// fallback — if a wakeup is ever lost, the single parked worker never
    /// resumes and the 5-second receive below times the test out.
    #[test]
    fn parked_worker_always_wakes_on_submit() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(1));
        let echo = sys.spawn(|_| Behavior::new().on(|_c, &x: &u32| reply(x)));
        let me = sys.scoped();
        let t0 = Instant::now();
        for i in 0..300u32 {
            // vary the idle gap so the submit lands at different points of
            // the worker's going-to-sleep window
            std::thread::sleep(Duration::from_millis((i % 3) as u64));
            let r: u32 = me
                .request(&echo, i)
                .receive(Duration::from_secs(5))
                .expect("lost wakeup: parked worker never resumed");
            assert_eq!(r, i);
        }
        // generous bound; a reintroduced poll-based sleep (300 x 10 ms
        // floor) would trip it even on a loaded machine
        assert!(t0.elapsed() < Duration::from_secs(30));
        sys.shutdown();
    }

    /// Regression stress for the *other* lost-wakeup window, the
    /// RUNNING→IDLE exit in `ActorCell::resume`: the IDLE store plus the
    /// mailbox recheck form a Dekker handshake with a sender's `schedule()`
    /// CAS. Without the SeqCst fence between store and recheck (and SeqCst
    /// on the CAS), a message can land with neither side scheduling the
    /// actor, which then stalls forever. Request/response round-trips put
    /// every follow-up enqueue right at that exit window; a lost wakeup
    /// surfaces as a receive timeout.
    #[test]
    fn idle_transition_never_loses_enqueue() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let echo = sys.spawn(|_| Behavior::new().on(|_c, &x: &u32| reply(x)));
        std::thread::scope(|s| {
            for t in 0..2u32 {
                let sys = &sys;
                let echo = echo.clone();
                s.spawn(move || {
                    let me = sys.scoped();
                    for i in 0..10_000u32 {
                        let v = (t << 16) | i;
                        let r: u32 = me
                            .request(&echo, v)
                            .receive(Duration::from_secs(5))
                            .expect("lost wakeup: actor stalled with a queued message");
                        assert_eq!(r, v);
                    }
                });
            }
        });
        sys.shutdown();
    }

    /// An external job must never be stuck behind one busy worker: with
    /// worker 0 occupied by a long-running handler, a fresh submission
    /// must still run promptly on the other worker via the shared
    /// injector.
    #[test]
    fn external_jobs_not_pinned_behind_busy_worker() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let sleeper = sys.spawn(|_| {
            Behavior::new().on(|_c, &ms: &u64| {
                std::thread::sleep(Duration::from_millis(ms));
                no_reply()
            })
        });
        let me = sys.scoped();
        // occupy one worker for ~1.5 s
        me.send(&sleeper, 1500u64);
        std::thread::sleep(Duration::from_millis(50));
        // every quick job must complete while the sleeper still runs
        let quick = sys.spawn(|_| Behavior::new().on(|_c, &x: &u32| reply(x * 2)));
        let t0 = Instant::now();
        for i in 0..20u32 {
            let r: u32 = me
                .request(&quick, i)
                .receive(Duration::from_secs(5))
                .expect("job starved behind busy worker");
            assert_eq!(r, i * 2);
        }
        assert!(
            t0.elapsed() < Duration::from_millis(1200),
            "quick jobs waited for the busy worker: {:?}",
            t0.elapsed()
        );
        sys.shutdown();
    }

    #[test]
    fn external_submit_storm_all_jobs_run() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(4));
        let actors: Vec<_> = (0..16)
            .map(|_| sys.spawn(|_| Behavior::new().on(|_c, &x: &u64| reply(x + 1))))
            .collect();
        let threads = 8;
        let per = 250u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let sys = &sys;
                let actors = &actors;
                s.spawn(move || {
                    let me = sys.scoped();
                    for i in 0..per {
                        let target = &actors[(t * 31 + i as usize * 7) % actors.len()];
                        let r: u64 = me
                            .request(target, i)
                            .receive(Duration::from_secs(10))
                            .expect("request lost in storm");
                        assert_eq!(r, i + 1);
                    }
                });
            }
        });
        sys.shutdown();
    }

    #[test]
    fn fire_and_forget_counts_via_sink() {
        use std::sync::atomic::AtomicUsize;
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let sink = sys.spawn(move |_| {
            let h = h.clone();
            Behavior::new().on(move |_c, _: &u32| {
                h.fetch_add(1, Ordering::SeqCst);
                no_reply()
            })
        });
        let me = sys.scoped();
        for i in 0..5000u32 {
            me.send(&sink, i);
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while hits.load(Ordering::SeqCst) < 5000 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(hits.load(Ordering::SeqCst), 5000);
        sys.shutdown();
    }
}
