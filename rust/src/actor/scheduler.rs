//! Cooperative work-stealing scheduler (CAF §2.1: "actors are implemented
//! as sub-thread entities and run in a cooperative scheduler using
//! work-stealing").
//!
//! N worker threads each own a local deque; spawns/wakeups from worker
//! threads go to the local deque, external submissions to a shared injector.
//! Idle workers steal from the injector first, then from victims' deques.

use super::cell::{ActorCell, ResumeResult};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Runnable = Arc<ActorCell>;

struct Shared {
    injector: Mutex<VecDeque<Runnable>>,
    locals: Vec<Mutex<VecDeque<Runnable>>>,
    sleepers: Mutex<usize>,
    wakeup: Condvar,
    shutdown: AtomicBool,
    throughput: usize,
    /// total messages processed (metrics)
    resumes: AtomicUsize,
}

thread_local! {
    /// Which worker the current thread is (usize::MAX = external thread).
    static WORKER_INDEX: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

pub struct Scheduler {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Scheduler {
    pub fn new(n_workers: usize, throughput: usize) -> Scheduler {
        let n = n_workers.max(1);
        let shared = Arc::new(Shared {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleepers: Mutex::new(0),
            wakeup: Condvar::new(),
            shutdown: AtomicBool::new(false),
            throughput,
            resumes: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("caf-worker-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    .expect("spawn scheduler worker")
            })
            .collect();
        Scheduler {
            shared,
            workers: Mutex::new(workers),
        }
    }

    /// Enqueue an actor for execution.
    pub fn submit(&self, cell: Runnable) {
        let idx = WORKER_INDEX.with(|w| w.get());
        if idx < self.shared.locals.len() {
            self.shared.locals[idx].lock().unwrap().push_back(cell);
        } else {
            self.shared.injector.lock().unwrap().push_back(cell);
        }
        // wake one sleeper if any
        if *self.shared.sleepers.lock().unwrap() > 0 {
            self.shared.wakeup.notify_one();
        }
    }

    pub fn n_workers(&self) -> usize {
        self.shared.locals.len()
    }

    /// Total scheduler slices executed so far (metrics).
    pub fn resume_count(&self) -> usize {
        self.shared.resumes.load(Ordering::Relaxed)
    }

    /// Stop all workers; queued actors are dropped.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wakeup.notify_all();
        let mut ws = self.workers.lock().unwrap();
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, index: usize) {
    WORKER_INDEX.with(|w| w.set(index));
    let n = shared.locals.len();
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let job = pop_job(&shared, index, n);
        match job {
            Some(cell) => {
                shared.resumes.fetch_add(1, Ordering::Relaxed);
                if let ResumeResult::Reschedule = cell.resume(shared.throughput) {
                    shared.locals[index].lock().unwrap().push_back(cell);
                }
            }
            None => {
                // sleep until new work arrives
                let mut sleepers = shared.sleepers.lock().unwrap();
                *sleepers += 1;
                let (mut sleepers2, _timeout) = shared
                    .wakeup
                    .wait_timeout(sleepers, std::time::Duration::from_millis(10))
                    .unwrap();
                *sleepers2 -= 1;
            }
        }
    }
}

fn pop_job(shared: &Shared, index: usize, n: usize) -> Option<Runnable> {
    if let Some(c) = shared.locals[index].lock().unwrap().pop_front() {
        return Some(c);
    }
    if let Some(c) = shared.injector.lock().unwrap().pop_front() {
        return Some(c);
    }
    // steal: scan victims starting after ourselves
    for k in 1..n {
        let v = (index + k) % n;
        if let Some(c) = shared.locals[v].lock().unwrap().pop_back() {
            return Some(c);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_starts_and_stops() {
        let s = Scheduler::new(4, 25);
        assert_eq!(s.n_workers(), 4);
        s.shutdown();
    }

    #[test]
    fn zero_workers_clamped_to_one() {
        let s = Scheduler::new(0, 25);
        assert_eq!(s.n_workers(), 1);
        s.shutdown();
    }
}
