//! Blocking (scoped) actors: thread-bound mailboxes for interacting with
//! the actor system from ordinary threads (CAF's `scoped_actor`), used by
//! examples, tests, and benches (`request(...).receive(...)`).
//!
//! Delivery into a scoped actor is lock-free (Vyukov MPSC push; the
//! sender only touches a mutex when the receiver is actually asleep, in
//! which case a wake syscall is unavoidable anyway). The receiving side
//! serializes scans with a consumer mutex that is **released while
//! waiting** (`Condvar::wait_timeout` + `notify_all`), so several threads
//! sharing one scoped actor can each make progress; out-of-order traffic
//! is buffered and replayed in arrival order.

use super::envelope::{ActorId, Envelope, MessageId};
use super::message::Message;
use super::monitor::ErrorMsg;
use super::system::ActorSystem;
use super::{AbstractActor, ActorRef};
use crate::concurrent::CountedQueue;
use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{fence, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct SharedBox {
    id: ActorId,
    /// Producer side: lock-free MPSC delivery.
    inbox: CountedQueue<Envelope>,
    /// Number of receivers committed to waiting (Dekker flag: senders
    /// only take `buffered` + notify when this is non-zero).
    waiting: AtomicUsize,
    /// Consumer side: serializes receivers; holds envelopes popped while
    /// scanning for a specific response. Released during waits.
    buffered: Mutex<VecDeque<Envelope>>,
    wakeup: Condvar,
}

impl AbstractActor for SharedBox {
    fn enqueue(&self, env: Envelope) {
        // scoped inboxes are never closed while reachable
        let _ = self.inbox.push(env);
        // Dekker handshake with the receiver's announce-then-recheck: if
        // the receiver missed this envelope, it has already bumped
        // `waiting`, so we see it here and deliver the wakeup.
        // pairs with: blocking.rs::receive_any (waiting-bump → fence → recheck)
        fence(Ordering::SeqCst);
        if self.waiting.load(Ordering::SeqCst) > 0 {
            // taking the consumer mutex orders this notify after the
            // receiver's wait registration — no lost wakeup
            let _g = self.buffered.lock().unwrap_or_else(|p| p.into_inner());
            self.wakeup.notify_all();
        }
    }

    fn id(&self) -> ActorId {
        self.id
    }

    fn attach_monitor(&self, _watcher: ActorRef) {}

    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "scoped"
    }
}

/// A thread-bound blocking actor.
pub struct ScopedActor {
    system: ActorSystem,
    inbox: Arc<SharedBox>,
}

/// Awaitable response of [`ScopedActor::request`].
pub struct PendingResponse<'a> {
    owner: &'a ScopedActor,
    mid: MessageId,
}

impl ScopedActor {
    pub(crate) fn new(system: ActorSystem, id: ActorId) -> ScopedActor {
        ScopedActor {
            system,
            inbox: Arc::new(SharedBox {
                id,
                inbox: CountedQueue::new(),
                waiting: AtomicUsize::new(0),
                buffered: Mutex::new(VecDeque::new()),
                wakeup: Condvar::new(),
            }),
        }
    }

    pub fn me(&self) -> ActorRef {
        ActorRef::new(self.inbox.clone() as Arc<dyn AbstractActor>)
    }

    pub fn system(&self) -> &ActorSystem {
        &self.system
    }

    /// Fire-and-forget send with this scoped actor as sender.
    pub fn send<T: Any + Send + Sync>(&self, target: &ActorRef, v: T) {
        target.enqueue(Envelope::asynchronous(Some(self.me()), Message::new(v)));
    }

    /// Issue a request; await it with [`PendingResponse::receive`].
    pub fn request<T: Any + Send + Sync>(&self, target: &ActorRef, v: T) -> PendingResponse<'_> {
        self.request_msg(target, Message::new(v))
    }

    pub fn request_msg(&self, target: &ActorRef, m: Message) -> PendingResponse<'_> {
        let mid = MessageId::fresh_request();
        target.enqueue(Envelope {
            sender: Some(self.me()),
            mid,
            msg: m,
        });
        PendingResponse { owner: self, mid }
    }

    /// Pop the next envelope, blocking up to `timeout`.
    pub fn receive_any(&self, timeout: Duration) -> Option<Envelope> {
        self.receive_where(timeout, |_| true)
    }

    /// Wait for the response correlated to `mid`, buffering (and keeping)
    /// any unrelated traffic that arrives meanwhile.
    fn await_response(&self, mid: MessageId, timeout: Duration) -> Result<Message, ErrorMsg> {
        let want = mid.response_for();
        match self.receive_where(timeout, |e| e.mid == want) {
            Some(env) => match env.msg.downcast_ref::<ErrorMsg>() {
                Some(e) => Err(e.clone()),
                None => Ok(env.msg),
            },
            None => Err(ErrorMsg::new("request timed out")),
        }
    }

    /// Core receive loop: return the first envelope matching `pred`
    /// (buffered traffic first, in arrival order), waiting up to
    /// `timeout`. Non-matching envelopes stay buffered.
    fn receive_where<F>(&self, timeout: Duration, pred: F) -> Option<Envelope>
    where
        F: Fn(&Envelope) -> bool,
    {
        let sb = &*self.inbox;
        let deadline = Instant::now() + timeout;
        let mut buf = sb.buffered.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(pos) = buf.iter().position(|e| pred(e)) {
                return buf.remove(pos);
            }
            // drain fresh arrivals; inbox pops are MPSC-single-consumer,
            // which holding `buffered` guarantees
            let mut matched = None;
            while let Some(e) = sb.inbox.pop() {
                if matched.is_none() && pred(&e) {
                    matched = Some(e);
                } else {
                    buf.push_back(e);
                }
            }
            if matched.is_some() {
                // other waiters may now match something we just buffered
                sb.wakeup.notify_all();
                return matched;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            // announce, then re-check the inbox before sleeping (the
            // producer pushes, fences, then reads `waiting`)
            sb.waiting.fetch_add(1, Ordering::SeqCst);
            // pairs with: blocking.rs::enqueue (push → fence → waiting load)
            fence(Ordering::SeqCst);
            if sb.inbox.is_empty() {
                let (g, _) = sb.wakeup.wait_timeout(buf, deadline - now).unwrap_or_else(|p| p.into_inner());
                buf = g;
            }
            sb.waiting.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

impl Drop for ScopedActor {
    fn drop(&mut self) {
        self.system.actor_terminated(self.inbox.id);
    }
}

impl PendingResponse<'_> {
    /// Await the raw response message.
    pub fn receive_msg(self, timeout: Duration) -> Result<Message, ErrorMsg> {
        self.owner.await_response(self.mid, timeout)
    }

    /// Await and extract a typed response.
    pub fn receive<R: Any + Clone>(self, timeout: Duration) -> Result<R, ErrorMsg> {
        let msg = self.receive_msg(timeout)?;
        msg.take::<R>().ok_or_else(|| {
            ErrorMsg::new(format!(
                "response type mismatch: got {}",
                msg.type_name()
            ))
        })
    }
}
