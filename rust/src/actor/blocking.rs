//! Blocking (scoped) actors: thread-bound mailboxes for interacting with
//! the actor system from ordinary threads (CAF's `scoped_actor`), used by
//! examples, tests, and benches (`request(...).receive(...)`).

use super::envelope::{ActorId, Envelope, MessageId};
use super::message::Message;
use super::monitor::ErrorMsg;
use super::system::ActorSystem;
use super::{AbstractActor, ActorRef};
use std::any::Any;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct SharedBox {
    id: ActorId,
    queue: Mutex<VecDeque<Envelope>>,
    cv: Condvar,
}

impl AbstractActor for SharedBox {
    fn enqueue(&self, env: Envelope) {
        self.queue.lock().unwrap().push_back(env);
        self.cv.notify_all();
    }

    fn id(&self) -> ActorId {
        self.id
    }

    fn attach_monitor(&self, _watcher: ActorRef) {}

    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "scoped"
    }
}

/// A thread-bound blocking actor.
pub struct ScopedActor {
    system: ActorSystem,
    inbox: Arc<SharedBox>,
}

/// Awaitable response of [`ScopedActor::request`].
pub struct PendingResponse<'a> {
    owner: &'a ScopedActor,
    mid: MessageId,
}

impl ScopedActor {
    pub(crate) fn new(system: ActorSystem, id: ActorId) -> ScopedActor {
        ScopedActor {
            system,
            inbox: Arc::new(SharedBox {
                id,
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
            }),
        }
    }

    pub fn me(&self) -> ActorRef {
        ActorRef::new(self.inbox.clone() as Arc<dyn AbstractActor>)
    }

    pub fn system(&self) -> &ActorSystem {
        &self.system
    }

    /// Fire-and-forget send with this scoped actor as sender.
    pub fn send<T: Any + Send + Sync>(&self, target: &ActorRef, v: T) {
        target.enqueue(Envelope::asynchronous(Some(self.me()), Message::new(v)));
    }

    /// Issue a request; await it with [`PendingResponse::receive`].
    pub fn request<T: Any + Send + Sync>(&self, target: &ActorRef, v: T) -> PendingResponse<'_> {
        self.request_msg(target, Message::new(v))
    }

    pub fn request_msg(&self, target: &ActorRef, m: Message) -> PendingResponse<'_> {
        let mid = MessageId::fresh_request();
        target.enqueue(Envelope {
            sender: Some(self.me()),
            mid,
            msg: m,
        });
        PendingResponse { owner: self, mid }
    }

    /// Pop the next envelope, blocking up to `timeout`.
    pub fn receive_any(&self, timeout: Duration) -> Option<Envelope> {
        let deadline = Instant::now() + timeout;
        let mut q = self.inbox.queue.lock().unwrap();
        loop {
            if let Some(e) = q.pop_front() {
                return Some(e);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (q2, _) = self
                .inbox
                .cv
                .wait_timeout(q, deadline - now)
                .unwrap();
            q = q2;
        }
    }

    /// Wait for the response correlated to `mid`, buffering (and keeping)
    /// any unrelated traffic that arrives meanwhile.
    fn await_response(&self, mid: MessageId, timeout: Duration) -> Result<Message, ErrorMsg> {
        let want = mid.response_for();
        let deadline = Instant::now() + timeout;
        let mut q = self.inbox.queue.lock().unwrap();
        loop {
            if let Some(pos) = q.iter().position(|e| e.mid == want) {
                let env = q.remove(pos).unwrap();
                return match env.msg.downcast_ref::<ErrorMsg>() {
                    Some(e) => Err(e.clone()),
                    None => Ok(env.msg),
                };
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(ErrorMsg::new("request timed out"));
            }
            let (q2, _) = self
                .inbox
                .cv
                .wait_timeout(q, deadline - now)
                .unwrap();
            q = q2;
        }
    }
}

impl Drop for ScopedActor {
    fn drop(&mut self) {
        self.system.actor_terminated(self.inbox.id);
    }
}

impl PendingResponse<'_> {
    /// Await the raw response message.
    pub fn receive_msg(self, timeout: Duration) -> Result<Message, ErrorMsg> {
        self.owner.await_response(self.mid, timeout)
    }

    /// Await and extract a typed response.
    pub fn receive<R: Any + Clone>(self, timeout: Duration) -> Result<R, ErrorMsg> {
        let msg = self.receive_msg(timeout)?;
        msg.take::<R>().ok_or_else(|| {
            ErrorMsg::new(format!(
                "response type mismatch: got {}",
                msg.type_name()
            ))
        })
    }
}
