//! Index-space configuration: the paper's `nd_range` / `dim_vec`
//! (Listing 2). On this substrate the index space is baked into the AOT
//! artifact's grid, so the range primarily serves interface fidelity,
//! validation, and device-occupancy accounting for the scheduler.

/// Up to three dimensions (OpenCL's NDRange limit).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct DimVec(pub Vec<usize>);

impl DimVec {
    pub fn d1(x: usize) -> DimVec {
        DimVec(vec![x])
    }

    pub fn d2(x: usize, y: usize) -> DimVec {
        DimVec(vec![x, y])
    }

    pub fn d3(x: usize, y: usize, z: usize) -> DimVec {
        DimVec(vec![x, y, z])
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn product(&self) -> usize {
        self.0.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

/// The execution index space (paper Listing 2/5):
/// global dimensions, optional global-id offsets, optional work-group
/// ("local") dimensions.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct NdRange {
    pub global: DimVec,
    pub offsets: DimVec,
    pub local: DimVec,
}

impl NdRange {
    pub fn new(global: DimVec) -> NdRange {
        NdRange {
            global,
            offsets: DimVec::default(),
            local: DimVec::default(),
        }
    }

    pub fn d1(x: usize) -> NdRange {
        Self::new(DimVec::d1(x))
    }

    pub fn d2(x: usize, y: usize) -> NdRange {
        Self::new(DimVec::d2(x, y))
    }

    pub fn with_local(mut self, local: DimVec) -> NdRange {
        self.local = local;
        self
    }

    pub fn with_offsets(mut self, offsets: DimVec) -> NdRange {
        self.offsets = offsets;
        self
    }

    /// Total work items (one kernel "execution" per item in OpenCL terms).
    pub fn work_items(&self) -> usize {
        self.global.product()
    }

    /// Work-group size, if local dimensions were given.
    pub fn work_group_size(&self) -> Option<usize> {
        if self.local.is_empty() {
            None
        } else {
            Some(self.local.product())
        }
    }

    /// Validate OpenCL constraints: rank <= 3, local divides global,
    /// work-group fits the device's processing elements.
    pub fn validate(&self, max_work_group: usize) -> Result<(), String> {
        if self.global.rank() == 0 || self.global.rank() > 3 {
            return Err(format!(
                "nd_range must have 1..=3 dimensions, got {}",
                self.global.rank()
            ));
        }
        if !self.local.is_empty() {
            if self.local.rank() != self.global.rank() {
                return Err("local rank must match global rank".to_string());
            }
            for (g, l) in self.global.0.iter().zip(&self.local.0) {
                if *l == 0 || g % l != 0 {
                    return Err(format!("local dim {l} does not divide global {g}"));
                }
            }
            let wg = self.local.product();
            if wg > max_work_group {
                return Err(format!(
                    "work-group size {wg} exceeds device limit {max_work_group}"
                ));
            }
        }
        Ok(())
    }

    /// Parse the `range=AxBxC` manifest extra.
    pub fn parse(s: &str) -> Option<NdRange> {
        let dims: Option<Vec<usize>> = s.split('x').map(|t| t.parse().ok()).collect();
        let dims = dims?;
        if dims.is_empty() || dims.len() > 3 {
            return None;
        }
        Some(NdRange::new(DimVec(dims)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_products() {
        let r = NdRange::d2(1024, 1024).with_local(DimVec::d2(16, 8));
        assert_eq!(r.work_items(), 1024 * 1024);
        assert_eq!(r.work_group_size(), Some(128));
    }

    #[test]
    fn validate_catches_bad_local() {
        let r = NdRange::d1(100).with_local(DimVec::d1(33));
        assert!(r.validate(1024).is_err());
        let r = NdRange::d1(128).with_local(DimVec::d1(128));
        assert!(r.validate(64).is_err()); // exceeds device limit
        assert!(r.validate(128).is_ok());
    }

    #[test]
    fn validate_rank() {
        assert!(NdRange::default().validate(1024).is_err());
        let r = NdRange::d2(8, 8).with_local(DimVec::d1(8));
        assert!(r.validate(1024).is_err()); // rank mismatch
    }

    #[test]
    fn parse_manifest_range() {
        assert_eq!(NdRange::parse("54x960").unwrap().work_items(), 54 * 960);
        assert!(NdRange::parse("1x2x3x4").is_none());
        assert!(NdRange::parse("abc").is_none());
    }
}
