//! One in-flight kernel execution (the paper's `command` class, Listing 4):
//! stage inputs, enqueue the kernel with its event dependencies, register
//! the completion callback, and *forward arguments before the execution
//! finished* — the asynchronous chaining that keeps multi-stage pipelines
//! free of host round-trips. Migrated `Ref`s (the placement tier's
//! device-to-device transfer path) arrive here like any other: their
//! staging copy is an event the launch simply depends on.

use super::arg::{ArgValue, Mode};
use super::device::Device;
use super::mem_ref::{Access, MemRef};
use crate::actor::request::ResponsePromise;
use crate::actor::Message;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::Event;
use std::sync::Arc;

/// Facade-side metrics (Fig 5: device time per request).
#[derive(Default)]
pub struct CommandStats {
    pub launched: std::sync::atomic::AtomicU64,
    pub device_ns: std::sync::atomic::AtomicU64,
}

/// Everything needed to launch one kernel invocation.
pub struct Command {
    pub device: Arc<Device>,
    pub meta: ArtifactMeta,
    pub args: Vec<ArgValue>,
    pub out_mode: Mode,
    pub promise: ResponsePromise,
    /// Maps the kernel output (plus the incoming message, so pipeline
    /// stages can re-pack context they must carry forward — §3.5: the
    /// post-processing function "could drop unnecessary output or reorder
    /// arguments to fit the next stage") to the response message.
    pub post: Option<Arc<dyn Fn(ArgValue, &Message) -> Message + Send + Sync>>,
    /// The message that triggered this command (preserved context).
    pub incoming: Message,
    pub stats: Option<Arc<CommandStats>>,
}

impl Command {
    /// Validate message arguments against the kernel signature.
    fn check(&self) -> Result<(), String> {
        if self.args.len() != self.meta.inputs.len() {
            return Err(format!(
                "kernel {} expects {} arguments, message carries {}",
                self.meta.name,
                self.meta.inputs.len(),
                self.args.len()
            ));
        }
        for (i, (a, spec)) in self.args.iter().zip(&self.meta.inputs).enumerate() {
            if a.dtype() != spec.dtype {
                return Err(format!(
                    "kernel {} argument {i}: expected {}, got {}",
                    self.meta.name,
                    spec.dtype.name(),
                    a.dtype().name()
                ));
            }
            if a.len() != spec.elems() {
                return Err(format!(
                    "kernel {} argument {i}: expected {} elements, got {}",
                    self.meta.name,
                    spec.elems(),
                    a.len()
                ));
            }
            if let ArgValue::Ref(r) = a {
                if !r.same_device(&self.device) {
                    // locality restriction of §3.5: references are bound to
                    // their device; crossing requires an explicit Val hop
                    return Err(format!(
                        "kernel {}: mem_ref on device {} used on device {}",
                        self.meta.name,
                        r.device_id(),
                        self.device.id
                    ));
                }
                if r.access() == Access::WriteOnly {
                    return Err(format!(
                        "kernel {}: write-only mem_ref used as input",
                        self.meta.name
                    ));
                }
            }
        }
        Ok(())
    }

    /// Enqueue the command (paper Listing 4's `enqueue`): all-`Val`
    /// argument lists go through the fused upload+execute submission (one
    /// command-channel traversal for the whole launch); argument lists
    /// carrying device references take the per-argument path — uploads for
    /// Val inputs, the kernel execution depending on every input event.
    /// Either way the response is an immediate `MemRef` (Ref output —
    /// forwarded before completion) or a download whose callback fulfills
    /// the promise (Val output).
    pub fn enqueue(self) {
        if let Err(e) = self.check() {
            self.promise
                .deliver_err(crate::actor::ErrorMsg::new(e));
            return;
        }
        let queue = &self.device.queue;
        let out_spec = self.meta.output.clone();
        let all_val = self.args.iter().all(|a| !a.is_ref());
        let (out_id, done) = if all_val {
            // fused fast path: the queue thread stages every input and runs
            // the kernel off one command, recycling the staged storage when
            // the launch retires — no Upload/Execute/Free triple
            let srcs: Vec<crate::runtime::UploadSrc> = self
                .args
                .iter()
                .map(|a| match a {
                    // zero host-side copy: the queue thread reads straight
                    // from the shared payload (clEnqueueWriteBuffer model)
                    ArgValue::U32(v) => crate::runtime::UploadSrc::SharedU32(v.clone()),
                    ArgValue::F32(v) => crate::runtime::UploadSrc::SharedF32(v.clone()),
                    ArgValue::Ref(_) => unreachable!("all_val checked"),
                })
                .collect();
            queue.execute_fused(&self.meta.name, srcs, out_spec.dtype)
        } else {
            let mut ids = Vec::with_capacity(self.args.len());
            let mut deps: Vec<Event> = Vec::new();
            let mut temps: Vec<u64> = Vec::new();
            for a in &self.args {
                match a {
                    ArgValue::Ref(r) => {
                        ids.push(r.buffer_id());
                        // lock-free fast path: a dependency that already
                        // retired successfully need not block the queue
                        // again; pending or failed events stay on the list
                        // so the queue thread waits or propagates the error
                        match r.ready_event().poll() {
                            Some(Ok(())) => {}
                            _ => deps.push(r.ready_event().clone()),
                        }
                    }
                    ArgValue::U32(v) => {
                        let (id, ev) = queue
                            .upload(crate::runtime::UploadSrc::SharedU32(v.clone()));
                        ids.push(id);
                        deps.push(ev);
                        temps.push(id);
                    }
                    ArgValue::F32(v) => {
                        let (id, ev) = queue
                            .upload(crate::runtime::UploadSrc::SharedF32(v.clone()));
                        ids.push(id);
                        deps.push(ev);
                        temps.push(id);
                    }
                }
            }
            let pair = queue.execute(&self.meta.name, ids, out_spec.dtype, deps);
            // inputs uploaded for this invocation die with it (in-order
            // queue: the Free retires after the Execute)
            for t in temps {
                queue.free(t);
            }
            pair
        };
        // Fig 5's "enqueue -> callback" window: for Ref outputs it ends at
        // kernel completion; for Val outputs it extends to the read-back,
        // matching the paper's "includes data transfer as well as the
        // kernel execution".
        if self.out_mode == Mode::Ref {
            if let Some(stats) = &self.stats {
                let st = stats.clone();
                let ev = done.clone();
                done.on_complete(move |_| {
                    st.launched
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if let Some(d) = ev.device_duration() {
                        st.device_ns.fetch_add(
                            d.as_nanos() as u64,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                    }
                });
            }
        }
        let post = self.post.clone();
        match self.out_mode {
            Mode::Ref => {
                // forward the reference NOW; the ready-event carries the
                // dependency to the next stage (§3.5)
                let r = MemRef::new(
                    self.device.clone(),
                    out_id,
                    out_spec.dtype,
                    out_spec.elems(),
                    Access::ReadWrite,
                    done,
                );
                let msg = match &post {
                    Some(p) => p(ArgValue::Ref(r), &self.incoming),
                    None => Message::new(r),
                };
                self.promise.deliver_msg(msg);
            }
            Mode::Val => {
                let promise = self.promise;
                let incoming = self.incoming;
                let q2 = queue.clone();
                let stats = self.stats.clone();
                let t_enqueue = std::time::Instant::now();
                queue.download_with(out_id, move |res| {
                    q2.free(out_id);
                    if let Some(st) = &stats {
                        st.launched
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        st.device_ns.fetch_add(
                            t_enqueue.elapsed().as_nanos() as u64,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                    }
                    match res {
                        Ok(host) => {
                            let arg = match host {
                                crate::runtime::HostData::U32(v) => {
                                    ArgValue::U32(Arc::new(v))
                                }
                                crate::runtime::HostData::F32(v) => {
                                    ArgValue::F32(Arc::new(v))
                                }
                            };
                            let msg = match &post {
                                Some(p) => p(arg, &incoming),
                                // shared Arcs must clone, never deliver the
                                // Default (empty!) vector — same fix as the
                                // batcher's default_msg
                                None => match arg {
                                    ArgValue::U32(v) => Message::new(
                                        Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone()),
                                    ),
                                    ArgValue::F32(v) => Message::new(
                                        Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone()),
                                    ),
                                    ArgValue::Ref(_) => unreachable!(),
                                },
                            };
                            promise.deliver_msg(msg);
                        }
                        Err(e) => {
                            promise.deliver_err(crate::actor::ErrorMsg::new(format!(
                                "kernel failed: {e}"
                            )));
                        }
                    }
                });
            }
        }
    }
}
