//! Kernel argument passing: host values vs device references.
//!
//! The paper's spawn declarations (`in<T>`, `out<T>`, `in_out<T>` with
//! optional `val`/`ref` tags, Listing 5) tell CAF how each kernel argument
//! crosses the actor boundary. Artifacts on this substrate have fixed
//! operand lists (the manifest), so the facade only needs the *mode* per
//! operand: `Val` moves data through the message (upload/download), `Ref`
//! passes device-resident [`MemRef`]s for pipelining.

use super::mem_ref::MemRef;
use crate::actor::Message;
use crate::runtime::artifact::Dtype;
use crate::runtime::HostData;
use std::sync::Arc;

/// How an operand crosses the actor boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Host values travel in messages; the facade copies to/from the device
    /// around each invocation (the basic OpenCL actor, §3.2).
    Val,
    /// Device references travel in messages; data stays resident (§3.5).
    Ref,
}

/// One kernel argument as carried by messages.
#[derive(Clone, Debug)]
pub enum ArgValue {
    U32(Arc<Vec<u32>>),
    F32(Arc<Vec<f32>>),
    Ref(MemRef),
}

impl ArgValue {
    pub fn dtype(&self) -> Dtype {
        match self {
            ArgValue::U32(_) => Dtype::U32,
            ArgValue::F32(_) => Dtype::F32,
            ArgValue::Ref(r) => r.dtype(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ArgValue::U32(v) => v.len(),
            ArgValue::F32(v) => v.len(),
            ArgValue::Ref(r) => r.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_ref(&self) -> bool {
        matches!(self, ArgValue::Ref(_))
    }

    pub(crate) fn to_host(&self) -> Option<HostData> {
        // the Arcs are shared with the message payload: unwrap when this is
        // the only owner (common for pipeline-internal args), clone
        // otherwise — halves the upload-path copies (EXPERIMENTS.md §Perf)
        match self {
            ArgValue::U32(v) => Some(HostData::U32(
                std::sync::Arc::try_unwrap(v.clone()).unwrap_or_else(|a| (*a).clone()),
            )),
            ArgValue::F32(v) => Some(HostData::F32(
                std::sync::Arc::try_unwrap(v.clone()).unwrap_or_else(|a| (*a).clone()),
            )),
            ArgValue::Ref(_) => None,
        }
    }
}

/// Value variants compare by content (what crosses the wire); `Ref`s
/// compare by identity (device + buffer), since two handles to the same
/// device allocation are interchangeable but distinct allocations are not
/// even when their contents happen to match.
impl PartialEq for ArgValue {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (ArgValue::U32(a), ArgValue::U32(b)) => a == b,
            (ArgValue::F32(a), ArgValue::F32(b)) => a == b,
            (ArgValue::Ref(a), ArgValue::Ref(b)) => {
                a.device_id() == b.device_id() && a.buffer_id() == b.buffer_id()
            }
            _ => false,
        }
    }
}

impl From<Vec<u32>> for ArgValue {
    fn from(v: Vec<u32>) -> Self {
        ArgValue::U32(Arc::new(v))
    }
}

impl From<Vec<f32>> for ArgValue {
    fn from(v: Vec<f32>) -> Self {
        ArgValue::F32(Arc::new(v))
    }
}

impl From<MemRef> for ArgValue {
    fn from(r: MemRef) -> Self {
        ArgValue::Ref(r)
    }
}

/// Default pattern matching: extract kernel arguments from the common
/// message shapes (the auto-generated "pattern for extracting data from
/// messages", §3.4). Custom extraction = a user `preprocess` function.
pub fn extract_args(msg: &Message) -> Option<Vec<ArgValue>> {
    if let Some(v) = msg.downcast_ref::<Vec<ArgValue>>() {
        return Some(v.clone());
    }
    if let Some(r) = msg.downcast_ref::<MemRef>() {
        return Some(vec![ArgValue::Ref(r.clone())]);
    }
    if let Some((a,)) = msg.downcast_ref::<(MemRef,)>() {
        return Some(vec![ArgValue::Ref(a.clone())]);
    }
    if let Some((a, b)) = msg.downcast_ref::<(MemRef, MemRef)>() {
        return Some(vec![ArgValue::Ref(a.clone()), ArgValue::Ref(b.clone())]);
    }
    if let Some(v) = msg.downcast_ref::<Vec<u32>>() {
        return Some(vec![ArgValue::U32(Arc::new(v.clone()))]);
    }
    if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        return Some(vec![ArgValue::F32(Arc::new(v.clone()))]);
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>)>() {
        return Some(vec![
            ArgValue::U32(Arc::new(a.clone())),
            ArgValue::U32(Arc::new(b.clone())),
        ]);
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<f32>, Vec<f32>)>() {
        return Some(vec![
            ArgValue::F32(Arc::new(a.clone())),
            ArgValue::F32(Arc::new(b.clone())),
        ]);
    }
    if let Some((a, b, c)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>, Vec<u32>)>() {
        return Some(vec![
            ArgValue::U32(Arc::new(a.clone())),
            ArgValue::U32(Arc::new(b.clone())),
            ArgValue::U32(Arc::new(c.clone())),
        ]);
    }
    None
}

/// Rewrite a message so every `Ref` argument is resident on `dst`,
/// migrating stragglers through the explicit device-to-device transfer
/// path ([`MemRef::migrate_to`]). Value arguments pass through untouched.
/// Returns `None` for messages the default patterns cannot extract — the
/// dispatcher falls back to the routed error there (a custom `preprocess`
/// shape is opaque to migration by design: rewriting it would have to
/// invert user code).
///
/// The rewrite is always to the canonical `Vec<ArgValue>` shape, which
/// every facade and the default `route_scan` accept; the original tuple
/// shape is not preserved.
pub(crate) fn migrate_message(
    msg: &Message,
    dst: &Arc<super::device::Device>,
) -> Option<Message> {
    let args = extract_args(msg)?;
    let moved: Vec<ArgValue> = args
        .into_iter()
        .map(|a| match a {
            ArgValue::Ref(r) => ArgValue::Ref(r.migrate_to(dst)),
            val => val,
        })
        .collect();
    Some(Message::new(moved))
}

/// Shape signature of an argument list: per-argument element counts plus
/// the dtype per argument — the identity of a batching *shape class* (two
/// requests coalesce into one fused launch iff their signatures match).
/// The dtype half is pinned to the manifest by per-request validation, so
/// for one kernel it is constant; keying on it anyway keeps class identity
/// self-contained rather than implicit in the kernel.
pub(crate) fn shape_sig(args: &[ArgValue]) -> (Vec<usize>, Vec<Dtype>) {
    (
        args.iter().map(|a| a.len()).collect(),
        args.iter().map(|a| a.dtype()).collect(),
    )
}

/// Affinity + cost inputs of one message, computed WITHOUT cloning any
/// payload data (`extract_args` deep-copies plain vectors, which would
/// double the per-message copy cost on the routed hot path just to learn
/// there are no refs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub(crate) struct RouteScan {
    /// Device ids (deduplicated, first-seen order) of the `Ref` arguments
    /// [`extract_args`] would produce.
    pub devices: Vec<usize>,
    /// Total byte size of the value arguments — what a launch would have
    /// to transfer to the device (the cost-aware policy's transfer input).
    pub val_bytes: usize,
}

impl RouteScan {
    /// Dedup-record one `Ref` argument's device — the single home of the
    /// first-seen-order dedup.
    pub(crate) fn note_ref(&mut self, d: usize) {
        if !self.devices.contains(&d) {
            self.devices.push(d);
        }
    }

    /// Fold one argument into the scan — the single place the Ref-device
    /// dedup and the value-byte accounting live, shared by the default
    /// shape scan below and the custom-`preprocess` path in `placement`
    /// (the two must stay mirror images or affinity semantics diverge).
    pub(crate) fn note_arg(&mut self, a: &ArgValue) {
        match a {
            ArgValue::Ref(r) => self.note_ref(r.device_id()),
            val => self.val_bytes += val.len() * 4,
        }
    }
}

/// Cheap routing scan for the placement dispatcher. Must mirror
/// [`extract_args`]' shape list: the plain-vector shapes can never carry
/// refs, so a type check plus a length read scans them. Returns `None`
/// for messages that do not extract at all.
pub(crate) fn route_scan(msg: &Message) -> Option<RouteScan> {
    if let Some(v) = msg.downcast_ref::<Vec<ArgValue>>() {
        let mut scan = RouteScan::default();
        for a in v {
            scan.note_arg(a);
        }
        return Some(scan);
    }
    if let Some(r) = msg.downcast_ref::<MemRef>() {
        return Some(RouteScan {
            devices: vec![r.device_id()],
            val_bytes: 0,
        });
    }
    if let Some((a,)) = msg.downcast_ref::<(MemRef,)>() {
        return Some(RouteScan {
            devices: vec![a.device_id()],
            val_bytes: 0,
        });
    }
    if let Some((a, b)) = msg.downcast_ref::<(MemRef, MemRef)>() {
        let mut scan = RouteScan::default();
        scan.note_ref(a.device_id());
        scan.note_ref(b.device_id());
        return Some(scan);
    }
    // the remaining extractable shapes are plain host vectors — no refs,
    // and the byte size is a length read away
    if let Some(v) = msg.downcast_ref::<Vec<u32>>() {
        return Some(RouteScan {
            devices: Vec::new(),
            val_bytes: v.len() * 4,
        });
    }
    if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        return Some(RouteScan {
            devices: Vec::new(),
            val_bytes: v.len() * 4,
        });
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>)>() {
        return Some(RouteScan {
            devices: Vec::new(),
            val_bytes: (a.len() + b.len()) * 4,
        });
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<f32>, Vec<f32>)>() {
        return Some(RouteScan {
            devices: Vec::new(),
            val_bytes: (a.len() + b.len()) * 4,
        });
    }
    if let Some((a, b, c)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>, Vec<u32>)>() {
        return Some(RouteScan {
            devices: Vec::new(),
            val_bytes: (a.len() + b.len() + c.len()) * 4,
        });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_common_shapes() {
        let m = Message::new(vec![1u32, 2, 3]);
        let args = extract_args(&m).unwrap();
        assert_eq!(args.len(), 1);
        assert_eq!(args[0].dtype(), Dtype::U32);
        assert_eq!(args[0].len(), 3);

        let m = Message::new((vec![1f32], vec![2f32]));
        let args = extract_args(&m).unwrap();
        assert_eq!(args.len(), 2);
        assert_eq!(args[1].dtype(), Dtype::F32);

        let m = Message::new("not args".to_string());
        assert!(extract_args(&m).is_none());
    }

    #[test]
    fn from_conversions() {
        let a: ArgValue = vec![1u32, 2].into();
        assert!(!a.is_ref());
        assert_eq!(a.to_host(), Some(HostData::U32(vec![1, 2])));
    }

    #[test]
    fn shape_sig_reports_lengths_and_dtypes_per_argument() {
        let args: Vec<ArgValue> = vec![vec![1u32, 2, 3].into(), vec![1.5f32].into()];
        let (lens, dtypes) = shape_sig(&args);
        assert_eq!(lens, vec![3, 1]);
        assert_eq!(dtypes, vec![Dtype::U32, Dtype::F32]);
        assert_eq!(shape_sig(&[]), (Vec::new(), Vec::new()));
    }

    #[test]
    fn route_scan_mirrors_extractable_shapes_without_cloning() {
        // plain-vector shapes extract but can never carry refs; the scan
        // reports their payload bytes for the cost-aware policy
        for (m, bytes) in [
            (Message::new(vec![1u32, 2]), 8),
            (Message::new(vec![1f32]), 4),
            (Message::new((vec![1u32], vec![2u32])), 8),
            (Message::new((vec![1f32], vec![2f32])), 8),
            (Message::new((vec![1u32], vec![2u32], vec![3u32])), 12),
            (Message::new(vec![ArgValue::from(vec![1u32])]), 4),
        ] {
            let scan = route_scan(&m).unwrap_or_else(|| panic!("{}", m.type_name()));
            assert_eq!(scan.devices, Vec::<usize>::new(), "{}", m.type_name());
            assert_eq!(scan.val_bytes, bytes, "{}", m.type_name());
        }
        // unextractable messages scan to None, like extract_args
        assert_eq!(route_scan(&Message::new("nope".to_string())), None);
    }
}
