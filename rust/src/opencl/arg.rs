//! Kernel argument passing: host values vs device references.
//!
//! The paper's spawn declarations (`in<T>`, `out<T>`, `in_out<T>` with
//! optional `val`/`ref` tags, Listing 5) tell CAF how each kernel argument
//! crosses the actor boundary. Artifacts on this substrate have fixed
//! operand lists (the manifest), so the facade only needs the *mode* per
//! operand: `Val` moves data through the message (upload/download), `Ref`
//! passes device-resident [`MemRef`]s for pipelining.

use super::mem_ref::MemRef;
use crate::actor::Message;
use crate::runtime::artifact::Dtype;
use crate::runtime::HostData;
use std::sync::Arc;

/// How an operand crosses the actor boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Host values travel in messages; the facade copies to/from the device
    /// around each invocation (the basic OpenCL actor, §3.2).
    Val,
    /// Device references travel in messages; data stays resident (§3.5).
    Ref,
}

/// One kernel argument as carried by messages.
#[derive(Clone, Debug)]
pub enum ArgValue {
    U32(Arc<Vec<u32>>),
    F32(Arc<Vec<f32>>),
    Ref(MemRef),
}

impl ArgValue {
    pub fn dtype(&self) -> Dtype {
        match self {
            ArgValue::U32(_) => Dtype::U32,
            ArgValue::F32(_) => Dtype::F32,
            ArgValue::Ref(r) => r.dtype(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ArgValue::U32(v) => v.len(),
            ArgValue::F32(v) => v.len(),
            ArgValue::Ref(r) => r.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_ref(&self) -> bool {
        matches!(self, ArgValue::Ref(_))
    }

    pub(crate) fn to_host(&self) -> Option<HostData> {
        // the Arcs are shared with the message payload: unwrap when this is
        // the only owner (common for pipeline-internal args), clone
        // otherwise — halves the upload-path copies (EXPERIMENTS.md §Perf)
        match self {
            ArgValue::U32(v) => Some(HostData::U32(
                std::sync::Arc::try_unwrap(v.clone()).unwrap_or_else(|a| (*a).clone()),
            )),
            ArgValue::F32(v) => Some(HostData::F32(
                std::sync::Arc::try_unwrap(v.clone()).unwrap_or_else(|a| (*a).clone()),
            )),
            ArgValue::Ref(_) => None,
        }
    }
}

/// Value variants compare by content (what crosses the wire); `Ref`s
/// compare by identity (device + buffer), since two handles to the same
/// device allocation are interchangeable but distinct allocations are not
/// even when their contents happen to match.
impl PartialEq for ArgValue {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (ArgValue::U32(a), ArgValue::U32(b)) => a == b,
            (ArgValue::F32(a), ArgValue::F32(b)) => a == b,
            (ArgValue::Ref(a), ArgValue::Ref(b)) => {
                a.device_id() == b.device_id() && a.buffer_id() == b.buffer_id()
            }
            _ => false,
        }
    }
}

impl From<Vec<u32>> for ArgValue {
    fn from(v: Vec<u32>) -> Self {
        ArgValue::U32(Arc::new(v))
    }
}

impl From<Vec<f32>> for ArgValue {
    fn from(v: Vec<f32>) -> Self {
        ArgValue::F32(Arc::new(v))
    }
}

impl From<MemRef> for ArgValue {
    fn from(r: MemRef) -> Self {
        ArgValue::Ref(r)
    }
}

/// Default pattern matching: extract kernel arguments from the common
/// message shapes (the auto-generated "pattern for extracting data from
/// messages", §3.4). Custom extraction = a user `preprocess` function.
pub fn extract_args(msg: &Message) -> Option<Vec<ArgValue>> {
    if let Some(v) = msg.downcast_ref::<Vec<ArgValue>>() {
        return Some(v.clone());
    }
    if let Some(r) = msg.downcast_ref::<MemRef>() {
        return Some(vec![ArgValue::Ref(r.clone())]);
    }
    if let Some((a,)) = msg.downcast_ref::<(MemRef,)>() {
        return Some(vec![ArgValue::Ref(a.clone())]);
    }
    if let Some((a, b)) = msg.downcast_ref::<(MemRef, MemRef)>() {
        return Some(vec![ArgValue::Ref(a.clone()), ArgValue::Ref(b.clone())]);
    }
    if let Some(v) = msg.downcast_ref::<Vec<u32>>() {
        return Some(vec![ArgValue::U32(Arc::new(v.clone()))]);
    }
    if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        return Some(vec![ArgValue::F32(Arc::new(v.clone()))]);
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>)>() {
        return Some(vec![
            ArgValue::U32(Arc::new(a.clone())),
            ArgValue::U32(Arc::new(b.clone())),
        ]);
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<f32>, Vec<f32>)>() {
        return Some(vec![
            ArgValue::F32(Arc::new(a.clone())),
            ArgValue::F32(Arc::new(b.clone())),
        ]);
    }
    if let Some((a, b, c)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>, Vec<u32>)>() {
        return Some(vec![
            ArgValue::U32(Arc::new(a.clone())),
            ArgValue::U32(Arc::new(b.clone())),
            ArgValue::U32(Arc::new(c.clone())),
        ]);
    }
    None
}

/// Cheap affinity scan for the placement dispatcher: the device ids of the
/// `Ref` arguments [`extract_args`] would produce, WITHOUT cloning any
/// payload data (`extract_args` deep-copies plain vectors, which would
/// double the per-message copy cost on the routed hot path just to learn
/// there are no refs). Must mirror `extract_args`' shape list: the
/// plain-vector shapes can never carry refs, so a type check alone scans
/// them to an empty list. Returns `None` for messages that do not extract
/// at all.
pub(crate) fn ref_device_scan(msg: &Message) -> Option<Vec<usize>> {
    fn dedup_push(devs: &mut Vec<usize>, d: usize) {
        if !devs.contains(&d) {
            devs.push(d);
        }
    }
    if let Some(v) = msg.downcast_ref::<Vec<ArgValue>>() {
        let mut devs = Vec::new();
        for a in v {
            if let ArgValue::Ref(r) = a {
                dedup_push(&mut devs, r.device_id());
            }
        }
        return Some(devs);
    }
    if let Some(r) = msg.downcast_ref::<MemRef>() {
        return Some(vec![r.device_id()]);
    }
    if let Some((a,)) = msg.downcast_ref::<(MemRef,)>() {
        return Some(vec![a.device_id()]);
    }
    if let Some((a, b)) = msg.downcast_ref::<(MemRef, MemRef)>() {
        let mut devs = vec![a.device_id()];
        dedup_push(&mut devs, b.device_id());
        return Some(devs);
    }
    // the remaining extractable shapes are plain host vectors — no refs
    if msg.is::<Vec<u32>>()
        || msg.is::<Vec<f32>>()
        || msg.is::<(Vec<u32>, Vec<u32>)>()
        || msg.is::<(Vec<f32>, Vec<f32>)>()
        || msg.is::<(Vec<u32>, Vec<u32>, Vec<u32>)>()
    {
        return Some(Vec::new());
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_common_shapes() {
        let m = Message::new(vec![1u32, 2, 3]);
        let args = extract_args(&m).unwrap();
        assert_eq!(args.len(), 1);
        assert_eq!(args[0].dtype(), Dtype::U32);
        assert_eq!(args[0].len(), 3);

        let m = Message::new((vec![1f32], vec![2f32]));
        let args = extract_args(&m).unwrap();
        assert_eq!(args.len(), 2);
        assert_eq!(args[1].dtype(), Dtype::F32);

        let m = Message::new("not args".to_string());
        assert!(extract_args(&m).is_none());
    }

    #[test]
    fn from_conversions() {
        let a: ArgValue = vec![1u32, 2].into();
        assert!(!a.is_ref());
        assert_eq!(a.to_host(), Some(HostData::U32(vec![1, 2])));
    }

    #[test]
    fn ref_scan_mirrors_extractable_shapes_without_cloning() {
        // plain-vector shapes extract but can never carry refs
        for m in [
            Message::new(vec![1u32, 2]),
            Message::new(vec![1f32]),
            Message::new((vec![1u32], vec![2u32])),
            Message::new((vec![1f32], vec![2f32])),
            Message::new((vec![1u32], vec![2u32], vec![3u32])),
            Message::new(vec![ArgValue::from(vec![1u32])]),
        ] {
            assert_eq!(ref_device_scan(&m), Some(Vec::new()), "{}", m.type_name());
        }
        // unextractable messages scan to None, like extract_args
        assert_eq!(ref_device_scan(&Message::new("nope".to_string())), None);
    }
}
