//! The OpenCL-actor integration (the paper's contribution, §3), transplanted
//! onto the PJRT substrate:
//!
//! * [`manager`]   — actor-system module; lazy platform discovery; `spawn`
//!   for OpenCL actors (paper Fig 2's `manager`).
//! * [`platform`]  — wraps the "driver" view: devices + the artifact
//!   manifest (the kernel "sources" of this substrate).
//! * [`device`]    — a compute device with its in-order command queue.
//! * [`program`]   — compiled kernels by name (paper Fig 2's `program`).
//! * [`nd_range`]  — index-space configuration (`nd_range`, `dim_vec`).
//! * [`arg`]       — kernel argument passing: value vs device-reference
//!   modes (the `in<T, val|ref>` tags of Listing 5).
//! * [`mem_ref`]   — device-resident buffer handles (`mem_ref<T>`).
//! * [`facade`]    — the OpenCL actor itself (`actor_facade`).
//! * [`command`]   — one in-flight kernel execution (paper Listing 4).
//! * [`stage`]     — kernel pipelines over resident memory (§3.5): the
//!   composed baseline plus `PipelineSpawn`, the placement-tier pipeline
//!   unit (per-device stage chains behind one driver actor, interleaved
//!   or lock-step scheduling).
//! * [`placement`] — multi-device replication: one replica facade per
//!   device behind a policy-routing, replica-supervising dispatcher
//!   (`Placement::Replicated`; round-robin / least-inflight / cost-aware
//!   policies, `Down`-driven failover and respawn, device subsets).
//!   Entire pipelines replicate as units, and an opt-in migration path
//!   moves stranded intermediate `Ref`s off dead or overloaded replicas
//!   instead of answering with a routed error.
//! * [`batch`]     — adaptive request batching: sub-capacity val-mode
//!   requests coalesced into padded fused launches.
//! * [`admission`] — bounded admission control for replicated spawns:
//!   load shedding at an inflight bound plus per-request queue-wait
//!   deadlines on local dispatch.

pub mod admission;
pub mod arg;
pub mod batch;
pub mod command;
pub mod device;
pub mod facade;
pub mod manager;
pub mod mem_ref;
pub mod nd_range;
pub mod placement;
pub mod platform;
pub mod program;
pub mod stage;

pub use admission::{Admission, AdmissionConfig, AdmissionStats, Rejection, ShedPolicy, Stamped};
pub use arg::{ArgValue, Mode};
pub use batch::BatchConfig;
pub use device::{Device, DeviceInfo, DeviceKind};
pub use facade::{FacadeStats, KernelSpawn};
pub use manager::{Manager, OpenClSystemExt};
pub use mem_ref::MemRef;
pub use nd_range::{DimVec, NdRange};
pub use placement::{
    DevicePool, Placement, PlacementPolicy, Replica, ReplicaSet, ReplicatedHandle,
    RespawnPolicy,
};
pub use platform::{DeviceSpec, Platform};
pub use program::Program;
pub use stage::{post_pair_from, PipelineBuilder, PipelineMode, PipelineSpawn};
