//! Platform: the "driver entry point" — device inventory plus the kernel
//! manifest (paper Fig 2's `platform`, which wraps the `cl_context`).

use super::device::{Device, DeviceInfo, DeviceKind};
use crate::runtime::client::PadModel;
use crate::runtime::Manifest;
use anyhow::Result;
use std::sync::Arc;

/// Configuration of one device to instantiate at discovery time. Real
/// hardware would be enumerated from the driver; this substrate creates a
/// PJRT CPU queue per spec, shaped by an optional simulated profile
/// (`sim::devices` provides Tesla/Phi/GTX specs).
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: String,
    pub kind: DeviceKind,
    pub info: DeviceInfo,
    pub pad: Option<PadModel>,
}

impl DeviceSpec {
    /// The plain PJRT CPU device (no simulation).
    pub fn host() -> DeviceSpec {
        DeviceSpec {
            name: "pjrt-cpu".to_string(),
            kind: DeviceKind::Cpu,
            info: DeviceInfo {
                compute_units: std::thread::available_parallelism()
                    .map(|n| n.get() as u32)
                    .unwrap_or(4),
                max_work_items_per_cu: 1,
            },
            pad: None,
        }
    }
}

/// A discovered platform: devices + manifest.
pub struct Platform {
    pub name: String,
    pub devices: Vec<Arc<Device>>,
    pub manifest: Manifest,
}

impl Platform {
    /// "Discover" the platform: load the manifest and start one queue
    /// thread per device spec.
    pub fn discover(artifacts_dir: &str, specs: &[DeviceSpec]) -> Result<Platform> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mut devices = Vec::new();
        for (id, spec) in specs.iter().enumerate() {
            devices.push(Device::start(id, &spec.name, spec.kind, spec.info, spec.pad)?);
        }
        Ok(Platform {
            name: "pjrt".to_string(),
            devices,
            manifest,
        })
    }

    pub fn device(&self, id: usize) -> Option<&Arc<Device>> {
        self.devices.get(id)
    }

    /// First device of a kind, mirroring OpenCL's
    /// `clGetDeviceIDs(CL_DEVICE_TYPE_GPU, ...)` selection.
    pub fn device_of_kind(&self, kind: DeviceKind) -> Option<&Arc<Device>> {
        self.devices.iter().find(|d| d.kind == kind)
    }

    /// Shut down all device queues.
    pub fn stop(&self) {
        for d in &self.devices {
            d.queue.stop();
        }
    }
}

impl std::fmt::Debug for Platform {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Platform({}, {} devices, {} kernels)",
            self.name,
            self.devices.len(),
            self.manifest.len()
        )
    }
}
