//! Device-resident buffer handles: the paper's `mem_ref<T>` (Fig 2, §3.5).
//!
//! A `MemRef` represents data living on an OpenCL device; messages between
//! pipeline stages carry only these references, so intermediate results
//! never cross the host boundary. A reference may be forwarded *before* the
//! kernel producing it finished — the attached ready-event carries the
//! dependency to the consuming stage (the paper's event-chained
//! asynchronous scheduling).
//!
//! A `MemRef` is bound to its local device/process; serializing one over
//! the network is a checked error (design option (a), §3.5).

use super::device::Device;
use crate::runtime::artifact::Dtype;
use crate::runtime::{Event, HostData};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Duration;

/// Buffer access rights (OpenCL buffer flags; enforced at facade level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    ReadWrite,
    ReadOnly,
    WriteOnly,
}

struct Inner {
    device: Arc<Device>,
    id: u64,
    dtype: Dtype,
    len: usize,
    access: Access,
    /// Completes when the producing command retired.
    ready: Event,
}

impl Drop for Inner {
    fn drop(&mut self) {
        // releasing the last reference frees the device memory ("dropping a
        // reference argument simply releases its memory on the device")
        self.device.queue.free(self.id);
    }
}

/// A typed reference to device memory. Cheap to clone; the underlying
/// buffer is freed when the last clone drops.
#[derive(Clone)]
pub struct MemRef {
    inner: Arc<Inner>,
}

impl MemRef {
    pub(crate) fn new(
        device: Arc<Device>,
        id: u64,
        dtype: Dtype,
        len: usize,
        access: Access,
        ready: Event,
    ) -> MemRef {
        MemRef {
            inner: Arc::new(Inner {
                device,
                id,
                dtype,
                len,
                access,
                ready,
            }),
        }
    }

    pub fn device_id(&self) -> usize {
        self.inner.device.id
    }

    pub(crate) fn buffer_id(&self) -> u64 {
        self.inner.id
    }

    pub fn dtype(&self) -> Dtype {
        self.inner.dtype
    }

    /// Number of elements (the paper: a reference carries "the amount of
    /// bytes it refers to" — elements * 4 here).
    pub fn len(&self) -> usize {
        self.inner.len
    }

    pub fn is_empty(&self) -> bool {
        self.inner.len == 0
    }

    pub fn bytes(&self) -> usize {
        self.inner.len * self.inner.dtype.byte_size()
    }

    pub fn access(&self) -> Access {
        self.inner.access
    }

    /// The producing command's completion event.
    pub fn ready_event(&self) -> &Event {
        &self.inner.ready
    }

    pub(crate) fn same_device(&self, dev: &Device) -> bool {
        self.inner.device.id == dev.id
    }

    /// Copy the data back to the host (the explicit transfer of §3.5 —
    /// "usually handled by the framework" via a Val-output stage, but
    /// available for direct inspection).
    pub fn read(&self, timeout: Duration) -> Result<HostData> {
        self.inner
            .ready
            .wait(timeout)
            .map_err(|e| anyhow!("producer failed: {e}"))?;
        self.inner.device.queue.download(self.inner.id, timeout)
    }

    /// Migrate this buffer to another device: an explicit device-to-device
    /// transfer ([`DeviceQueue::transfer_to`](crate::runtime::client::DeviceQueue::transfer_to),
    /// download-from-src + upload-to-dst) that mints a new reference on
    /// `dst` whose ready-event completes when the copy lands. The hop rides
    /// the source's in-order queue, so it observes the producing command —
    /// a failed producer fails the migrated ref's ready-event, and the
    /// consuming command surfaces that error exactly like any other failed
    /// dependency. Already-resident refs are returned as cheap clones.
    ///
    /// This is what turns a stranded-`Ref` routed error into a reschedule:
    /// the dispatcher prices the move via `PadModel::transfer_time` (both
    /// sides pay their pad) and re-delegates to a live replica.
    pub fn migrate_to(&self, dst: &Arc<Device>) -> MemRef {
        if self.same_device(dst) {
            return self.clone();
        }
        let (new_id, ready) = self.inner.device.queue.transfer_to(self.inner.id, &dst.queue);
        MemRef::new(
            dst.clone(),
            new_id,
            self.inner.dtype,
            self.inner.len,
            self.inner.access,
            ready,
        )
    }

    pub fn read_u32(&self, timeout: Duration) -> Result<Vec<u32>> {
        self.read(timeout)?.into_u32()
    }

    pub fn read_f32(&self, timeout: Duration) -> Result<Vec<f32>> {
        self.read(timeout)?.into_f32()
    }
}

impl std::fmt::Debug for MemRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MemRef(dev={}, buf={}, {}[{}], ready={})",
            self.inner.device.id,
            self.inner.id,
            self.inner.dtype.name(),
            self.inner.len,
            self.inner.ready.is_complete()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opencl::device::{Device, DeviceInfo, DeviceKind};
    use crate::runtime::HostData;
    use std::time::Duration;

    const T: Duration = Duration::from_secs(30);

    fn test_device(id: usize) -> Arc<Device> {
        Device::start(
            id,
            "memref-test",
            DeviceKind::Cpu,
            DeviceInfo {
                compute_units: 1,
                max_work_items_per_cu: 1,
            },
            None,
        )
        .unwrap()
    }

    // needs the stub's recycling hook; the pool is force-disabled without it
    #[cfg(feature = "xla-stub")]
    #[test]
    fn dropping_last_clone_frees_into_pool() {
        let dev = test_device(7);
        let (id, ev) = dev.queue.upload(HostData::U32(vec![5u32; 1024]));
        let r = MemRef::new(dev.clone(), id, Dtype::U32, 1024, Access::ReadWrite, ev);
        let r2 = r.clone();
        assert_eq!(r2.read_u32(T).unwrap(), vec![5u32; 1024]);

        drop(r);
        dev.queue.barrier(T).unwrap();
        let (_, _, returned, _) = dev.queue.stats().pool_snapshot();
        assert_eq!(returned, 0, "a live clone must keep the buffer resident");

        drop(r2);
        dev.queue.barrier(T).unwrap();
        let (hits_before, _, returned, _) = dev.queue.stats().pool_snapshot();
        assert_eq!(returned, 1, "last drop must return the buffer to the pool");

        // a fresh same-size-class upload recycles the freed buffer
        let (id2, ev2) = dev.queue.upload(HostData::U32(vec![9u32; 1000]));
        ev2.wait(T).unwrap();
        let (hits_after, _, _, _) = dev.queue.stats().pool_snapshot();
        assert_eq!(hits_after, hits_before + 1, "upload must recycle the pooled buffer");
        let back = dev.queue.download(id2, T).unwrap().into_u32().unwrap();
        assert_eq!(back, vec![9u32; 1000]);
        dev.queue.stop();
    }

    #[test]
    fn migrate_to_moves_bytes_across_devices() {
        let src = test_device(10);
        let dst = test_device(11);
        let want: Vec<u32> = (0..512u32).collect();
        let (id, ev) = src.queue.upload(HostData::U32(want.clone()));
        let r = MemRef::new(src.clone(), id, Dtype::U32, 512, Access::ReadWrite, ev);
        let moved = r.migrate_to(&dst);
        assert_eq!(moved.device_id(), 11);
        assert_eq!(moved.read_u32(T).unwrap(), want);
        assert_eq!(src.queue.stats().migrations(), 1);
        // the source copy is untouched and still readable
        assert_eq!(r.read_u32(T).unwrap(), want);
        // same-device migration is a clone, not a copy
        let same = r.migrate_to(&src);
        assert_eq!(same.device_id(), 10);
        assert_eq!(src.queue.stats().migrations(), 1);
        src.queue.stop();
        dst.queue.stop();
    }

    #[test]
    fn buffer_stays_resident_while_any_clone_lives() {
        let dev = test_device(8);
        let (id, ev) = dev.queue.upload(HostData::U32((0..256u32).collect()));
        let r = MemRef::new(dev.clone(), id, Dtype::U32, 256, Access::ReadWrite, ev);
        let clones: Vec<MemRef> = (0..5).map(|_| r.clone()).collect();
        drop(r);
        for c in clones {
            // every clone can still read; the free only happens at the end
            assert_eq!(c.read(T).unwrap().len(), 256);
        }
        dev.queue.barrier(T).unwrap();
        // the Free retires exactly once: returned to the pool with the
        // stub's recycling hook, evicted without it (pool force-disabled)
        let (_, _, returned, evicted) = dev.queue.stats().pool_snapshot();
        #[cfg(feature = "xla-stub")]
        assert_eq!((returned, evicted), (1, 0));
        #[cfg(not(feature = "xla-stub"))]
        assert_eq!((returned, evicted), (0, 1));
        dev.queue.stop();
    }
}
