//! Programs: compiled kernels retrievable by name (paper Fig 2's `program`
//! — "stores compiled OpenCL kernels and provides a mapping from kernel
//! names to objects").
//!
//! The OpenCL flow compiles source strings at runtime; here the "sources"
//! are AOT HLO-text artifacts, compiled on the device's queue thread at
//! program-creation time — same lifecycle, same laziness.

use super::device::Device;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::Manifest;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// A set of kernels compiled for one device.
pub struct Program {
    device: Arc<Device>,
    kernels: HashMap<String, ArtifactMeta>,
}

impl Program {
    /// Compile `names` from the manifest onto `device` (blocking until the
    /// device reports compilation done — OpenCL's `clBuildProgram`).
    pub fn build(
        device: Arc<Device>,
        manifest: &Manifest,
        names: &[&str],
        timeout: Duration,
    ) -> Result<Arc<Program>> {
        let mut kernels = HashMap::new();
        let mut pending = Vec::new();
        for name in names {
            let meta = manifest.get(name)?;
            // `emu=<op>` extras route to host emulation (stub-backend
            // kernels, runtime::client::HostOp); everything else is a real
            // HLO artifact
            let ev = match meta.extras.get("emu") {
                Some(op) => {
                    let op = crate::runtime::HostOp::parse(op)
                        .ok_or_else(|| anyhow!("kernel {name}: unknown emu op {op:?}"))?;
                    device.queue.compile_emulated(*name, op)
                }
                None => device.queue.compile(*name, manifest.hlo_path(meta)),
            };
            pending.push((name.to_string(), ev));
            kernels.insert(name.to_string(), meta.clone());
        }
        for (name, ev) in pending {
            ev.wait(timeout)
                .map_err(|e| anyhow!("building kernel {name}: {e}"))?;
        }
        Ok(Arc::new(Program { device, kernels }))
    }

    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    /// Look up a kernel's operand signature.
    pub fn kernel(&self, name: &str) -> Result<&ArtifactMeta> {
        self.kernels
            .get(name)
            .ok_or_else(|| anyhow!("kernel {name:?} not in program"))
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.kernels.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }
}

impl std::fmt::Debug for Program {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Program(device={}, {} kernels)",
            self.device.name,
            self.kernels.len()
        )
    }
}
