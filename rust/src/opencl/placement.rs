//! Placement-aware multi-device execution: one logical OpenCL actor served
//! by a replica facade per device, behind a single dispatcher `ActorRef`.
//!
//! The paper pins every facade to a single device chosen at spawn time
//! (§3.6: "the OpenCL device binding for a kernel defaults to the first
//! discovered device") and observes in §5 that "for sub-second duties, the
//! efficiency of offloading was found to largely differ between devices".
//! This module lifts the spawn-frozen binding into a routed decision per
//! message: [`Manager::spawn_cl`] with [`Placement::Replicated`] spawns one
//! facade per discovered device (each with the kernel compiled on *its*
//! device) and returns a dispatcher that fans traffic out by a pluggable
//! [`PlacementPolicy`], while callers keep the paper's one-actor illusion —
//! the dispatcher is an ordinary [`ActorRef`], publishable over
//! [`net::Node`](crate::net::Node) like any other actor, so remote clients
//! get placement for free.
//!
//! Routing invariants:
//!
//! * **Affinity** — a message whose [`ArgValue::Ref`]s are resident on
//!   device D always routes to D's replica. What used to be a per-command
//!   "mem_ref on device X used on device Y" error (the silent-wrong-device
//!   hazard of a spawn-frozen binding) becomes a routed guarantee.
//! * **Least-inflight** — reads the per-device queue-depth gauge
//!   ([`ExecStats::inflight`](crate::runtime::ExecStats::inflight)) and
//!   picks the shallowest queue, which is what spreads a burst of
//!   sub-second requests across the whole inventory.
//! * **Round-robin** — stateless rotation for uniform devices.
//!
//! [`Manager::spawn_cl`]: super::manager::Manager::spawn_cl

use super::arg::ArgValue;
use super::device::Device;
use super::facade::{spawn_on_device, KernelSpawn};
use super::manager::Manager;
use super::program::Program;
use crate::actor::{ActorRef, Behavior, ErrorMsg, Reply};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Where a spawned OpenCL actor runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Placement {
    /// One facade on the device the spawn's program was built for — the
    /// paper's behavior, and the default.
    #[default]
    Pinned,
    /// One facade on the given device id (the program is rebuilt there if
    /// it was compiled for another device).
    Device(usize),
    /// One replica facade per discovered device behind a dispatcher that
    /// routes each message by `PlacementPolicy` (Ref-carrying messages
    /// always follow their data — see the module docs).
    Replicated(PlacementPolicy),
}

/// How the dispatcher picks a replica for messages that carry no
/// device-resident arguments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Rotate through the replicas.
    RoundRobin,
    /// Pick the device with the shallowest submit-but-not-retired queue
    /// (the `ExecStats::inflight` gauge).
    LeastInflight,
}

/// One replica of a replicated OpenCL actor: the device it is bound to and
/// the facade serving it.
pub struct Replica {
    pub device: Arc<Device>,
    pub facade: ActorRef,
    /// Messages the dispatcher has routed here (feeds the queue-depth
    /// estimate; see [`DevicePool::depth`]).
    routed: AtomicU64,
}

impl Replica {
    pub fn new(device: Arc<Device>, facade: ActorRef) -> Replica {
        Replica {
            device,
            facade,
            routed: AtomicU64::new(0),
        }
    }
}

/// The replica set + policy a dispatcher routes over.
pub struct DevicePool {
    replicas: Vec<Replica>,
    policy: PlacementPolicy,
    next_rr: AtomicUsize,
    /// Whether [`depth`](DevicePool::depth) may use the routed-minus-
    /// retired estimate. Off for batched replicas: the dispatcher counts
    /// `routed` once per *request* but a batcher launches once per
    /// *flush*, so the two totals never reconcile and the residue would
    /// permanently skew least-inflight routing.
    routed_estimate: bool,
}

impl DevicePool {
    /// Build a pool; panics on an empty replica set (spawn paths guard
    /// against an empty inventory before constructing one).
    pub fn new(replicas: Vec<Replica>, policy: PlacementPolicy) -> DevicePool {
        assert!(!replicas.is_empty(), "DevicePool needs at least one replica");
        DevicePool {
            replicas,
            policy,
            next_rr: AtomicUsize::new(0),
            routed_estimate: true,
        }
    }

    /// Toggle the routed-depth estimate (see the field docs; the spawn
    /// path turns it off for batched replicas).
    pub fn set_routed_estimate(&mut self, on: bool) {
        self.routed_estimate = on;
    }

    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Route one message: `ref_devices` are the (deduplicated) device ids
    /// of its `ArgValue::Ref` arguments. Returns the replica index.
    pub fn route(&self, ref_devices: &[usize]) -> Result<usize, String> {
        match ref_devices {
            [] => Ok(self.select()),
            [d] => self
                .replicas
                .iter()
                .position(|r| r.device.id == *d)
                .ok_or_else(|| {
                    format!(
                        "mem_ref resident on device {d}, which has no replica \
                         (references cannot cross devices)"
                    )
                }),
            many => Err(format!(
                "arguments are resident on multiple devices {many:?}; \
                 split the request or copy through a Val-mode hop"
            )),
        }
    }

    /// Record that a message was routed to replica `i` (called by the
    /// dispatcher for messages whose arguments extracted successfully —
    /// those are the ones that will reach the device).
    pub fn note_routed(&self, i: usize) {
        self.replicas[i].routed.fetch_add(1, Ordering::Relaxed);
    }

    /// Queue-depth estimate of replica `i`: the larger of the device's own
    /// submitted-but-not-retired gauge and this dispatcher's
    /// routed-but-not-retired count. The latter is what makes a burst
    /// spread *at routing time* — the device gauge only rises once the
    /// replica facade has processed the message and submitted the launch,
    /// which an actor-mailbox hop later than the routing decision. A
    /// request that fails replica-side validation after extraction never
    /// launches and leaves the routed count slightly inflated; the
    /// estimate is a placement heuristic, so that skew only biases policy
    /// choice, never correctness.
    pub fn depth(&self, i: usize) -> u64 {
        let r = &self.replicas[i];
        let stats = r.device.queue.stats();
        if !self.routed_estimate {
            // batched replicas: one flush serves many routed requests, so
            // only the device's own gauge is meaningful
            return stats.inflight();
        }
        let retired = stats.launched().saturating_sub(stats.inflight());
        stats
            .inflight()
            .max(r.routed.load(Ordering::Relaxed).saturating_sub(retired))
    }

    /// Policy pick for affinity-free traffic.
    fn select(&self) -> usize {
        match self.policy {
            PlacementPolicy::RoundRobin => {
                self.next_rr.fetch_add(1, Ordering::Relaxed) % self.replicas.len()
            }
            PlacementPolicy::LeastInflight => {
                let mut best = 0usize;
                let mut best_depth = u64::MAX;
                for i in 0..self.replicas.len() {
                    let depth = self.depth(i);
                    if depth < best_depth {
                        best = i;
                        best_depth = depth;
                    }
                }
                best
            }
        }
    }
}

/// Device ids (deduplicated, in first-seen order) of the `Ref` arguments a
/// message carries. The default extraction goes through the clone-free
/// [`ref_device_scan`](super::arg) — the dispatcher must not deep-copy
/// every payload just to learn there are no refs. Custom `preprocess`
/// functions are called (their extraction defines affinity), which means
/// a `pre` with side effects runs once here and once in the replica; the
/// hook is documented as a pure conversion (Listing 3). `None` when the
/// message does not extract at all (it is still delegated — the replica
/// produces the proper error — but not counted as routed work).
fn ref_devices(
    cfg_pre: &Option<super::facade::PreFn>,
    msg: &crate::actor::Message,
) -> Option<Vec<usize>> {
    let Some(pre) = cfg_pre else {
        return super::arg::ref_device_scan(msg);
    };
    let args = pre(msg)?;
    let mut devs = Vec::new();
    for a in &args {
        if let ArgValue::Ref(r) = a {
            let d = r.device_id();
            if !devs.contains(&d) {
                devs.push(d);
            }
        }
    }
    Some(devs)
}

/// Spawn one replica facade per discovered device plus the dispatcher that
/// routes between them (used by `Manager::spawn_cl` for
/// [`Placement::Replicated`]).
pub(crate) fn spawn_replicated(
    mgr: &Manager,
    cfg: KernelSpawn,
    policy: PlacementPolicy,
) -> Result<ActorRef> {
    let platform = mgr.try_platform()?;
    if platform.devices.is_empty() {
        bail!(
            "cannot replicate kernel {:?}: device inventory is empty",
            cfg.kernel
        );
    }
    let sys = mgr.system_handle();
    let timeout = mgr.build_timeout();
    let mut replicas = Vec::with_capacity(platform.devices.len());
    for dev in &platform.devices {
        // reuse the caller's program on its own device; compile the kernel
        // for every other device (the manual multi-device flow of §3.2,
        // automated)
        let mut rcfg = cfg.clone();
        if rcfg.program.device().id != dev.id {
            rcfg.program = Program::build(
                dev.clone(),
                &platform.manifest,
                &[cfg.kernel.as_str()],
                timeout,
            )?;
        }
        let facade = spawn_on_device(&sys, rcfg, dev.clone())?;
        replicas.push(Replica::new(dev.clone(), facade));
    }
    let mut pool = DevicePool::new(replicas, policy);
    if cfg.batching.is_some() {
        pool.set_routed_estimate(false);
    }
    let pool = Arc::new(pool);
    Ok(spawn_dispatcher(&sys, pool, cfg.pre.clone(), cfg.kernel))
}

/// The dispatcher: an ordinary event-based actor that routes each message
/// to a replica via [`DevicePool::route`] and delegates it, so the replica
/// answers the original requester directly (no extra hop on the reply
/// path).
fn spawn_dispatcher(
    sys: &crate::actor::ActorSystem,
    pool: Arc<DevicePool>,
    pre: Option<super::facade::PreFn>,
    kernel: String,
) -> ActorRef {
    sys.spawn(move |_ctx| {
        let pool = pool.clone();
        let pre = pre.clone();
        let kernel = kernel.clone();
        Behavior::new().on_any(move |ctx, msg| {
            let devs = ref_devices(&pre, msg);
            let extracted = devs.is_some();
            match pool.route(devs.as_deref().unwrap_or(&[])) {
                Ok(i) => {
                    if extracted {
                        // count real work toward the routed-depth estimate
                        pool.note_routed(i);
                    }
                    ctx.delegate(&pool.replicas()[i].facade, msg.clone());
                }
                Err(e) => {
                    let promise = ctx.make_promise();
                    promise.deliver_err(ErrorMsg::new(format!("kernel {kernel}: {e}")));
                }
            }
            Reply::Promised
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::{ActorSystem, SystemConfig};
    use crate::opencl::device::{DeviceInfo, DeviceKind};
    use crate::runtime::client::PadModel;
    use std::time::Duration;

    fn test_device(id: usize, pad: Option<PadModel>) -> Arc<Device> {
        Device::start(
            id,
            &format!("pool-test-{id}"),
            DeviceKind::Cpu,
            DeviceInfo {
                compute_units: 1,
                max_work_items_per_cu: 1,
            },
            pad,
        )
        .unwrap()
    }

    fn dummy_ref(sys: &ActorSystem) -> ActorRef {
        sys.spawn(|_| Behavior::new().on_any(|_c, _m| Reply::Promised))
    }

    #[test]
    fn round_robin_rotates_and_affinity_overrides() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = DevicePool::new(
            vec![
                Replica::new(d0.clone(), dummy_ref(&sys)),
                Replica::new(d1.clone(), dummy_ref(&sys)),
            ],
            PlacementPolicy::RoundRobin,
        );
        assert_eq!(pool.route(&[]).unwrap(), 0);
        assert_eq!(pool.route(&[]).unwrap(), 1);
        assert_eq!(pool.route(&[]).unwrap(), 0);
        // affinity beats rotation
        assert_eq!(pool.route(&[1]).unwrap(), 1);
        assert_eq!(pool.route(&[0]).unwrap(), 0);
        // unknown device and cross-device refs are routed errors
        assert!(pool.route(&[7]).unwrap_err().contains("device 7"));
        assert!(pool.route(&[0, 1]).unwrap_err().contains("multiple devices"));
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn least_inflight_picks_the_idle_device() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        // device 0 is slow so a submitted launch stays in flight
        let slow = PadModel {
            launch: Duration::from_millis(80),
            bytes_per_sec: 0.0,
            compute_scale: 1.0,
            busy_wait: false,
        };
        let d0 = test_device(0, Some(slow));
        let d1 = test_device(1, None);
        let pool = DevicePool::new(
            vec![
                Replica::new(d0.clone(), dummy_ref(&sys)),
                Replica::new(d1.clone(), dummy_ref(&sys)),
            ],
            PlacementPolicy::LeastInflight,
        );
        // both idle: ties resolve to the first replica
        assert_eq!(pool.route(&[]).unwrap(), 0);
        // occupy device 0 (the gauge rises at submission time)
        d0.queue
            .compile_emulated("busy", crate::runtime::HostOp::Identity);
        let (bid, _ev) = d0.queue.upload(crate::runtime::HostData::U32(vec![1; 8]));
        let (_out, done) = d0
            .queue
            .execute("busy", vec![bid], crate::runtime::Dtype::U32, vec![]);
        assert!(d0.queue.stats().inflight() >= 1);
        assert_eq!(pool.route(&[]).unwrap(), 1, "idle device must win");
        done.wait(Duration::from_secs(30)).unwrap();
        d0.queue.barrier(Duration::from_secs(30)).unwrap();
        // drained: the gauge falls back to zero and ties go first again
        assert_eq!(d0.queue.stats().inflight(), 0);
        assert_eq!(pool.route(&[]).unwrap(), 0);
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn routed_depth_spreads_bursts_before_any_launch() {
        // the dispatcher-side estimate: routed-but-not-yet-launched work
        // biases routing away, so a burst spreads at routing time — the
        // device gauge alone would rise only after each replica facade had
        // processed its message
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = DevicePool::new(
            vec![
                Replica::new(d0.clone(), dummy_ref(&sys)),
                Replica::new(d1.clone(), dummy_ref(&sys)),
            ],
            PlacementPolicy::LeastInflight,
        );
        let mut picks = Vec::new();
        for _ in 0..6 {
            let i = pool.route(&[]).unwrap();
            pool.note_routed(i);
            picks.push(i);
        }
        assert_eq!(picks, vec![0, 1, 0, 1, 0, 1], "burst must alternate");
        assert_eq!(pool.depth(0), 3);
        assert_eq!(pool.depth(1), 3);
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn batched_pools_ignore_the_routed_estimate() {
        // a batcher launches once per flush, so per-request routed counts
        // can never reconcile against `launched`; with the estimate off,
        // depth falls back to the raw device gauge
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let mut pool = DevicePool::new(
            vec![
                Replica::new(d0.clone(), dummy_ref(&sys)),
                Replica::new(d1.clone(), dummy_ref(&sys)),
            ],
            PlacementPolicy::LeastInflight,
        );
        pool.set_routed_estimate(false);
        for _ in 0..5 {
            pool.note_routed(0);
        }
        assert_eq!(pool.depth(0), 0, "routed residue must not count");
        assert_eq!(pool.route(&[]).unwrap(), 0, "idle devices tie to first");
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }
}
