//! Placement-aware multi-device execution: one logical OpenCL actor served
//! by a replica facade per device, behind a single dispatcher `ActorRef`.
//!
//! The paper pins every facade to a single device chosen at spawn time
//! (§3.6: "the OpenCL device binding for a kernel defaults to the first
//! discovered device") and observes in §5 that "for sub-second duties, the
//! efficiency of offloading was found to largely differ between devices".
//! This module lifts the spawn-frozen binding into a routed decision per
//! message: [`Manager::spawn_cl`] with [`Placement::Replicated`] spawns one
//! facade per replica device (each with the kernel compiled on *its*
//! device) and returns a dispatcher that fans traffic out by a pluggable
//! [`PlacementPolicy`], while callers keep the paper's one-actor illusion —
//! the dispatcher is an ordinary [`ActorRef`], publishable over
//! [`net::Node`](crate::net::Node) like any other actor, so remote clients
//! get placement for free.
//!
//! Routing invariants:
//!
//! * **Affinity** — a message whose [`ArgValue::Ref`](super::arg::ArgValue)s
//!   are resident on device D always routes to D's replica. What used to be a per-command
//!   "mem_ref on device X used on device Y" error (the silent-wrong-device
//!   hazard of a spawn-frozen binding) becomes a routed guarantee.
//! * **Least-inflight** — reads the per-device queue-depth gauge
//!   ([`ExecStats::inflight`](crate::runtime::ExecStats::inflight)) and
//!   picks the shallowest queue, which is what spreads a burst of
//!   sub-second requests across the whole inventory. For *batched*
//!   replicas the depth source is the occupancy gauge the batcher itself
//!   publishes ([`ExecStats::batch_pending`](crate::runtime::ExecStats)) —
//!   admitted-but-unretired requests — because per-request routed counts
//!   can never reconcile against per-flush launches.
//! * **Cost-aware** — scores each live replica by estimated completion
//!   time (simulated dispatch latency + transfer time for the message's
//!   byte size + queue depth × mean service time from the per-device
//!   [`ExecStats::ewma_service`](crate::runtime::ExecStats::ewma_service)
//!   gauge) and picks the cheapest. This reproduces the Fig 7b lesson:
//!   small requests are steered *around* a Phi-like device whose
//!   per-command dispatch cost dwarfs the work.
//! * **Round-robin** — stateless rotation for uniform devices.
//!
//! Overload (see [`super::admission`]): when the spawn's
//! [`ReplicaSet::admission`] bounds admitted work, the dispatcher checks
//! [`DevicePool::total_depth`] before routing — past the bound it rejects
//! with a typed `Overloaded` error or sheds the stalest queued request
//! (`DropOldest`), and under a `max_queue_wait` deadline every routed
//! message is stamped with its admission instant so later stages can fail
//! it fast instead of serving a reply nobody is waiting for.
//!
//! Fault tolerance (the actor model's canonical failure signal, §2.1 "if
//! an actor dies unexpectedly, the runtime system sends a message to each
//! actor monitoring it"): the dispatcher monitors every replica facade.
//! On [`Down`] it marks the replica dead, stops selecting it, drains its
//! routed-depth contribution (a dead replica's routed-but-never-launched
//! messages must not skew least-inflight forever), answers affinity
//! traffic whose `Ref`s are stranded on the dead device with a routed
//! error, and — when the spawn's [`RespawnPolicy`] says so — respawns the
//! facade by recompiling the program on that device.
//! [`RespawnPolicy::Limited`] bounds that: each rebuild waits an
//! exponentially growing backoff, and once the per-replica budget is
//! spent the replica is retired permanently instead of crash-looping
//! compiles on the helper thread forever. Requests already delegated to a
//! dying facade are never lost silently: its closing mailbox bounces them
//! with an `actor terminated` error, so every routed request gets a reply
//! or an error, exactly once.
//!
//! **Pipelines as placement units** (paper §3.5 composed kernels, lifted):
//! [`spawn_pipeline_replicated`] compiles and spawns an *entire*
//! [`PipelineSpawn`] — every stage facade plus a per-replica driver — on
//! every replica device, behind the same dispatcher `ActorRef`. A request
//! routes once; every stage's `Ref` stays on the chosen device. The pool
//! reads the drivers' published occupancy gauge
//! ([`ExecStats::pipe_occupancy`](crate::runtime::ExecStats)) for depth
//! and prices cost-aware picks as entry transfer + per-stage launch pads +
//! depth × the end-to-end pipeline EWMA. Supervision treats the replica
//! pipeline as a unit: `Down` from ANY stage (or the driver) marks the
//! whole replica dead, the surviving members are taken down, and a respawn
//! recompiles ALL stages before reinstalling.
//!
//! **Migration** ([`ReplicaSet::migrate`], default off): instead of the
//! stranded-`Ref` routed error, the dispatcher picks a live replica as if
//! the request were affinity-free, migrates every `Ref` argument to its
//! device through the explicit device-to-device transfer path
//! ([`MemRef::migrate_to`](super::mem_ref::MemRef::migrate_to) — priced by
//! `PadModel::transfer_time` on both queues), and delegates the rewritten
//! request — a rescheduling event where there used to be an error.
//!
//! [`Manager::spawn_cl`]: super::manager::Manager::spawn_cl

use super::admission::{Admission, AdmissionConfig, Stamped};
use super::arg::RouteScan;
use super::device::Device;
use super::facade::{spawn_on_device, KernelSpawn, PreFn};
use super::manager::Manager;
use super::program::Program;
use super::stage::{pipeline_label, spawn_pipeline_driver, PipelineMode, PipelineSpawn};
use crate::actor::{
    ActorRef, ActorSystem, Behavior, Down, ErrorMsg, Exit, Message, Reply, no_reply,
};
use crate::runtime::Manifest;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Where a spawned OpenCL actor runs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum Placement {
    /// One facade on the device the spawn's program was built for — the
    /// paper's behavior, and the default.
    #[default]
    Pinned,
    /// One facade on the given device id (the program is rebuilt there if
    /// it was compiled for another device).
    Device(usize),
    /// One replica facade per device of the [`ReplicaSet`] behind a
    /// dispatcher that routes each message by its policy (Ref-carrying
    /// messages always follow their data — see the module docs).
    Replicated(ReplicaSet),
}

impl Placement {
    /// Replicate across the whole inventory with `policy` and the default
    /// [`RespawnPolicy`] (the common case).
    pub fn replicated(policy: PlacementPolicy) -> Placement {
        Placement::Replicated(ReplicaSet::new(policy))
    }
}

/// Configuration of a [`Placement::Replicated`] spawn: routing policy,
/// what to do when a replica dies, and (optionally) which devices to span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReplicaSet {
    /// How affinity-free traffic picks a replica.
    pub policy: PlacementPolicy,
    /// What the dispatcher does when a replica facade terminates.
    pub respawn: RespawnPolicy,
    /// Device ids to replicate on; `None` spans the whole inventory.
    /// Validated at spawn: every id must exist, no duplicates, non-empty.
    pub devices: Option<Vec<usize>>,
    /// Bounded admission: cap on admitted-but-unretired work, per-request
    /// queue-wait deadline, and the shed policy at the bound. The default
    /// admits everything (the pre-admission behavior). See
    /// [`AdmissionConfig`].
    pub admission: AdmissionConfig,
    /// Migrate stranded `Ref` traffic instead of erroring: when affinity
    /// routing fails (the resident replica is dead, retired, or the refs
    /// span devices), the dispatcher device-to-device-copies every `Ref`
    /// argument to a live replica and reroutes there, turning the routed
    /// error into a rescheduling event. Off by default — migration copies
    /// device memory through the host on the stub/emu backends, so the
    /// caller opts into paying that (pad-priced) cost.
    pub migrate: bool,
}

impl ReplicaSet {
    pub fn new(policy: PlacementPolicy) -> ReplicaSet {
        ReplicaSet {
            policy,
            respawn: RespawnPolicy::default(),
            devices: None,
            admission: AdmissionConfig::default(),
            migrate: false,
        }
    }

    /// Replicate only on the given device ids instead of the whole
    /// inventory.
    pub fn on_devices(mut self, ids: impl Into<Vec<usize>>) -> Self {
        self.devices = Some(ids.into());
        self
    }

    /// Set the respawn policy ([`RespawnPolicy::Never`] is the default).
    pub fn respawn(mut self, r: RespawnPolicy) -> Self {
        self.respawn = r;
        self
    }

    /// Set the admission bounds (unbounded is the default).
    pub fn admission(mut self, a: AdmissionConfig) -> Self {
        self.admission = a;
        self
    }

    /// Enable (or disable) stranded-`Ref` migration — see the field docs.
    pub fn migrate(mut self, on: bool) -> Self {
        self.migrate = on;
        self
    }
}

impl From<PlacementPolicy> for ReplicaSet {
    fn from(policy: PlacementPolicy) -> ReplicaSet {
        ReplicaSet::new(policy)
    }
}

/// How the dispatcher picks a replica for messages that carry no
/// device-resident arguments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Rotate through the live replicas.
    RoundRobin,
    /// Pick the device with the shallowest submit-but-not-retired queue
    /// (the `ExecStats::inflight` gauge).
    LeastInflight,
    /// Pick the replica with the lowest estimated completion time:
    /// simulated dispatch + transfer cost for the message's payload bytes
    /// ([`PadModel::transfer_time`](crate::runtime::client::PadModel))
    /// plus queue depth × mean per-launch service time (the
    /// `ExecStats::ewma_service` gauge). Steers small requests around
    /// high-dispatch-cost devices — the Fig 7b lesson.
    CostAware,
}

/// What the dispatcher does when a replica facade terminates (the actor
/// `Down` signal).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RespawnPolicy {
    /// Leave the replica dead; traffic reroutes to the survivors and
    /// affinity traffic for the dead device gets routed errors.
    #[default]
    Never,
    /// Recompile the program on the replica's device and respawn the
    /// facade on EVERY death, immediately and forever — the unbounded
    /// alias of [`Limited`](RespawnPolicy::Limited). A replica whose
    /// program deterministically fails will recompile on the helper
    /// thread on every death; prefer `Limited` when that is a concern.
    Always,
    /// Respawn at most `max` times per replica, sleeping an exponentially
    /// growing backoff before each rebuild (`backoff`, `2*backoff`,
    /// `4*backoff`, ...). A death after the budget is spent marks the
    /// replica *permanently dead* ([`Replica::is_retired`]): it is never
    /// rebuilt again, its traffic reroutes to the survivors, and the
    /// crash-loop stops burning the helper thread on doomed compiles.
    Limited { max: u32, backoff: Duration },
}

impl RespawnPolicy {
    /// Backoff to sleep before rebuild attempt `n` (1-based), or `None`
    /// when the policy does not allow another attempt.
    fn delay_for(self, n: u64) -> Option<Duration> {
        match self {
            RespawnPolicy::Never => None,
            RespawnPolicy::Always => Some(Duration::ZERO),
            RespawnPolicy::Limited { max, backoff } => {
                if n > max as u64 {
                    return None;
                }
                // exponential: backoff * 2^(n-1), saturating (the shift is
                // clamped so a huge attempt count cannot overflow the
                // multiplier before saturating_mul can clamp the product)
                let shift = (n - 1).min(31) as u32;
                Some(backoff.saturating_mul(1u32 << shift))
            }
        }
    }

    /// Sustained-healthy period after which a replica's cumulative
    /// [`Limited`](RespawnPolicy::Limited) respawn budget resets, or
    /// `None` when the policy has no budget to reset. The horizon is the
    /// policy's full backoff ladder (`backoff * 2^max` — the longest wait
    /// a crash-looper would reach) floored at 30 s: a replica that
    /// outlived the whole ladder plus a healthy margin is evidently not
    /// in the same crash loop, so its next death is fresh evidence — a
    /// replica that crashes once a week must not creep toward permanent
    /// retirement on a lifetime attempt counter.
    fn healthy_reset_after(self) -> Option<Duration> {
        const FLOOR: Duration = Duration::from_secs(30);
        match self {
            RespawnPolicy::Limited { max, backoff } => {
                Some(backoff.saturating_mul(1u32 << max.min(31)).max(FLOOR))
            }
            _ => None,
        }
    }
}

/// One replica of a replicated OpenCL actor: the device it is bound to and
/// the facade serving it (swapped on respawn), plus the dispatcher-side
/// liveness and routed-depth bookkeeping.
pub struct Replica {
    pub device: Arc<Device>,
    /// Current facade incarnation; replaced by [`DevicePool::install`]
    /// when a dead replica respawns.
    facade: RwLock<ActorRef>,
    /// Messages the dispatcher has routed here (feeds the queue-depth
    /// estimate; see [`DevicePool::depth`]). Re-synced to the device's
    /// retired count when the replica dies or respawns, so a dead
    /// incarnation's never-launched messages cannot skew routing forever.
    routed: AtomicU64,
    /// False between a `Down` and a successful respawn; dead replicas are
    /// never selected and affinity traffic for them is a routed error.
    alive: AtomicBool,
    /// Successful respawns of this replica (diagnostics/tests).
    respawns: AtomicU64,
    /// Rebuild attempts started (deaths that triggered a respawn) — what
    /// [`RespawnPolicy::Limited`] budgets against.
    attempts: AtomicU64,
    /// Permanently dead: the limited respawn budget is exhausted. Never
    /// rebuilt again (`alive` stays false for routing).
    retired: AtomicBool,
    /// When this incarnation (re)entered service — spawn or the last
    /// [`DevicePool::install`]. The healthy-period clock the respawn
    /// budget reset measures against.
    healthy_since: Mutex<Instant>,
    /// Length of the just-ended healthy period, frozen at death
    /// (nanoseconds; 0 = no completed period yet). Frozen rather than
    /// measured at decision time so a slow failed-rebuild loop — minutes
    /// of compile timeouts while the replica is actually *dead* — can
    /// never masquerade as a sustained healthy period.
    last_healthy_ns: AtomicU64,
    /// Stage facades owned by this replica when it fronts a whole pipeline
    /// (empty for single-kernel replicas; the `facade` is then the
    /// per-replica driver). `Down` from ANY member marks the replica dead
    /// as a unit, and a respawn replaces the full roster.
    members: Mutex<Vec<ActorRef>>,
}

impl Replica {
    pub fn new(device: Arc<Device>, facade: ActorRef) -> Replica {
        Replica {
            device,
            facade: RwLock::new(facade),
            routed: AtomicU64::new(0),
            alive: AtomicBool::new(true),
            respawns: AtomicU64::new(0),
            attempts: AtomicU64::new(0),
            retired: AtomicBool::new(false),
            healthy_since: Mutex::new(Instant::now()),
            last_healthy_ns: AtomicU64::new(0),
            members: Mutex::new(Vec::new()),
        }
    }

    /// The current facade incarnation (the per-replica driver when this
    /// replica fronts a pipeline).
    pub fn facade(&self) -> ActorRef {
        self.facade.read().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// The stage facades of the current incarnation (empty for
    /// single-kernel replicas) — the fault-injection surface for
    /// whole-pipeline supervision tests.
    pub fn members(&self) -> Vec<ActorRef> {
        self.members
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
    }

    pub(crate) fn set_members(&self, m: Vec<ActorRef>) {
        *self.members.lock().unwrap_or_else(|p| p.into_inner()) = m;
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Successful respawns so far.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Rebuild attempts started so far (cumulative across the replica's
    /// lifetime — [`RespawnPolicy::Limited`] budgets deaths, not
    /// consecutive failures, so a replica that keeps crashing converges on
    /// retirement instead of oscillating forever).
    pub fn respawn_attempts(&self) -> u64 {
        self.attempts.load(Ordering::Relaxed)
    }

    /// Permanently dead: the limited respawn budget is exhausted and this
    /// replica will never be rebuilt.
    pub fn is_retired(&self) -> bool {
        self.retired.load(Ordering::Acquire)
    }

    /// Count one rebuild-or-retire decision; returns the 1-based attempt
    /// number.
    fn note_attempt(&self) -> u64 {
        self.attempts.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Time since this incarnation (re)entered service.
    pub fn healthy_duration(&self) -> Duration {
        self.healthy_since
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .elapsed()
    }

    /// Restart the healthy-period clock (spawn / respawn install).
    fn mark_healthy(&self) {
        *self
            .healthy_since
            .lock()
            .unwrap_or_else(|p| p.into_inner()) = Instant::now();
    }

    /// Freeze the just-ended healthy period (called by `mark_dead`).
    fn note_death(&self) {
        let healthy = self.healthy_duration().as_nanos() as u64;
        self.last_healthy_ns.store(healthy, Ordering::Relaxed);
    }

    /// The respawn-budget reset rule: if the healthy period that just
    /// ended outlasted the policy's
    /// [`healthy_reset_after`](RespawnPolicy) horizon, the cumulative
    /// attempt count restarts at zero — this death is fresh evidence, not
    /// a continuation of an old crash loop. Called at the top of every
    /// rebuild decision; returns whether a non-zero budget was reset.
    fn maybe_reset_budget(&self, policy: RespawnPolicy) -> bool {
        let Some(horizon) = policy.healthy_reset_after() else {
            return false;
        };
        if self.attempts.load(Ordering::Relaxed) == 0 {
            return false;
        }
        let healthy = Duration::from_nanos(self.last_healthy_ns.load(Ordering::Relaxed));
        if healthy >= horizon {
            self.attempts.store(0, Ordering::Relaxed);
            return true;
        }
        false
    }
}

/// The replica set + policy a dispatcher routes over.
pub struct DevicePool {
    replicas: Vec<Replica>,
    policy: PlacementPolicy,
    next_rr: AtomicUsize,
    /// Whether the replicas are batching facades. The dispatcher counts
    /// `routed` once per *request* but a batcher launches once per
    /// *flush*, so the routed-minus-retired estimate can never reconcile
    /// there and its residue would permanently skew least-inflight
    /// routing; instead, [`depth`](DevicePool::depth) reads the occupancy
    /// gauge the batcher itself publishes
    /// ([`ExecStats::batch_pending`](crate::runtime::ExecStats)).
    batched: bool,
    /// Stage count when the replicas front whole pipelines (0 = plain
    /// single-kernel pool). A pipeline driver admits once per *request*
    /// but its device launches once per *stage*, so — like batching — the
    /// routed estimate cannot reconcile; depth reads the drivers'
    /// published occupancy gauge
    /// ([`ExecStats::pipe_occupancy`](crate::runtime::ExecStats)) and the
    /// cost model prices the full stage chain.
    pipeline_stages: usize,
}

impl DevicePool {
    /// Build a pool; an empty replica set is an `Err` (the fallible-spawn
    /// convention — spawn paths surface it instead of aborting).
    pub fn new(replicas: Vec<Replica>, policy: PlacementPolicy) -> Result<DevicePool> {
        if replicas.is_empty() {
            bail!("DevicePool needs at least one replica");
        }
        Ok(DevicePool {
            replicas,
            policy,
            next_rr: AtomicUsize::new(0),
            batched: false,
            pipeline_stages: 0,
        })
    }

    /// Mark the pool as fronting batching facades: the depth signal
    /// switches from the dispatcher's routed estimate to the batchers'
    /// published occupancy gauge (see the field docs; the spawn path sets
    /// this for `KernelSpawn::batched` replicas).
    pub fn set_batched(&mut self, on: bool) {
        self.batched = on;
    }

    /// Mark the pool as fronting `n`-stage pipeline drivers: depth reads
    /// the drivers' occupancy gauge and cost scoring prices entry transfer
    /// plus `n - 1` inter-stage launch pads against the end-to-end
    /// pipeline EWMA (see the field docs; set by
    /// [`spawn_pipeline_replicated`]).
    pub fn set_pipeline(&mut self, n: usize) {
        self.pipeline_stages = n;
    }

    /// Stage count of a pipeline pool (0 for single-kernel pools).
    pub fn pipeline_stages(&self) -> usize {
        self.pipeline_stages
    }

    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Replicas currently alive.
    pub fn live_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_alive()).count()
    }

    /// Mark the replica whose *current* facade — or, for pipeline
    /// replicas, any current stage member — has `source` as dead and
    /// drain its routed-depth contribution. Returns the replica index, or
    /// `None` when no live replica matches (e.g. a stale `Down` for an
    /// incarnation that was already replaced, or for a peer the dispatcher
    /// took down after the first member death already killed the replica).
    pub fn mark_dead(&self, source: crate::actor::ActorId) -> Option<usize> {
        let i = self.replicas.iter().position(|r| {
            r.is_alive()
                && (r.facade().id() == source
                    || r.members().iter().any(|m| m.id() == source))
        })?;
        self.replicas[i].note_death();
        self.replicas[i].alive.store(false, Ordering::Release);
        self.drain_routed(i);
        Some(i)
    }

    /// Install a freshly respawned facade for replica `i` and bring it
    /// back into rotation with a clean depth estimate. `alive` flips
    /// before the respawn counter bumps, so an observer gating on
    /// [`Replica::respawns`] never sees a respawned-but-dead replica.
    pub fn install(&self, i: usize, facade: ActorRef) {
        let r = &self.replicas[i];
        *r.facade.write().unwrap_or_else(|p| p.into_inner()) = facade;
        self.drain_routed(i);
        r.mark_healthy();
        r.alive.store(true, Ordering::Release);
        r.respawns.fetch_add(1, Ordering::Release);
    }

    /// Permanently retire replica `i`: its [`RespawnPolicy::Limited`]
    /// budget is exhausted, so it is never rebuilt and never selected
    /// again (`mark_dead` already took it out of rotation).
    pub fn retire(&self, i: usize) {
        self.replicas[i].retired.store(true, Ordering::Release);
    }

    /// Re-sync a replica's routed counter to the device's retired count:
    /// routed-but-never-launched messages of a dead incarnation bounced
    /// from its closed mailbox and will never retire, so leaving them in
    /// the counter would inflate [`depth`](DevicePool::depth) forever (the
    /// ROADMAP "stale routed estimate" bug).
    fn drain_routed(&self, i: usize) {
        let r = &self.replicas[i];
        let stats = r.device.queue.stats();
        let retired = stats.launched().saturating_sub(stats.inflight());
        r.routed.store(retired, Ordering::Relaxed);
    }

    /// Route one message: `ref_devices` are the (deduplicated) device ids
    /// of its `ArgValue::Ref` arguments, `bytes` its value-payload size
    /// (the cost-aware transfer estimate). Returns the replica index.
    pub fn route(&self, ref_devices: &[usize], bytes: usize) -> Result<usize, String> {
        match ref_devices {
            [] => self.select(bytes),
            [d] => {
                let i = self
                    .replicas
                    .iter()
                    .position(|r| r.device.id == *d)
                    .ok_or_else(|| {
                        format!(
                            "mem_ref resident on device {d}, which has no replica \
                             (references cannot cross devices)"
                        )
                    })?;
                if !self.replicas[i].is_alive() {
                    return Err(format!(
                        "replica on device {d} is down; mem_refs resident there \
                         cannot be served until it respawns"
                    ));
                }
                Ok(i)
            }
            many => Err(format!(
                "arguments are resident on multiple devices {many:?}; \
                 split the request or copy through a Val-mode hop"
            )),
        }
    }

    /// Record that a message was routed to replica `i` (called by the
    /// dispatcher for messages whose arguments extracted successfully —
    /// those are the ones that will reach the device).
    pub fn note_routed(&self, i: usize) {
        self.replicas[i].routed.fetch_add(1, Ordering::Relaxed);
    }

    /// Queue-depth estimate of replica `i`: the larger of the device's own
    /// submitted-but-not-retired gauge and this dispatcher's
    /// routed-but-not-retired count. The latter is what makes a burst
    /// spread *at routing time* — the device gauge only rises once the
    /// replica facade has processed the message and submitted the launch,
    /// which is an actor-mailbox hop later than the routing decision. A
    /// request that fails replica-side validation after extraction never
    /// launches and leaves the routed count slightly inflated; the
    /// estimate is a placement heuristic, so that skew only biases policy
    /// choice, never correctness — and a replica *death* drains the
    /// counter outright (see [`mark_dead`](DevicePool::mark_dead)).
    pub fn depth(&self, i: usize) -> u64 {
        let r = &self.replicas[i];
        let stats = r.device.queue.stats();
        if self.pipeline_stages > 0 {
            // pipeline replicas: the driver admits once per request but
            // the device launches once per stage, so routed-minus-retired
            // can never reconcile (the batching problem again). The signal
            // is the occupancy gauge the driver publishes — admitted but
            // unretired requests, lock-step waiters included — blended
            // (max) with the device's own launch gauge for unpipelined
            // co-tenants sharing the queue.
            return stats.pipe_occupancy().max(stats.inflight());
        }
        if self.batched {
            // batched replicas: one flush serves many routed requests, so
            // the dispatcher's routed counter cannot reconcile. The real
            // signal is the occupancy gauge the batcher publishes —
            // admitted-but-unflushed requests plus flushed-but-unretired
            // launches scaled by their request count — blended (max) with
            // the device's own launch gauge, which still covers unbatched
            // co-tenants sharing the device queue.
            return r.device.batch_occupancy().max(stats.inflight());
        }
        let retired = stats.launched().saturating_sub(stats.inflight());
        stats
            .inflight()
            .max(r.routed.load(Ordering::Relaxed).saturating_sub(retired))
    }

    /// Total admitted-but-unretired work across the pool: the sum of the
    /// per-replica [`depth`](DevicePool::depth) estimates, which is the
    /// gauge the admission bound
    /// ([`AdmissionConfig::max_inflight`](super::AdmissionConfig)) is
    /// enforced against. For batched pools the summand is the batchers'
    /// occupancy gauge, which rises one actor-mailbox hop after routing —
    /// so a storm can briefly over-admit by the messages in flight
    /// between dispatcher and batcher; the bound is a backpressure
    /// mechanism, not an exact semaphore.
    pub fn total_depth(&self) -> u64 {
        (0..self.replicas.len()).map(|i| self.depth(i)).sum()
    }

    /// Estimated completion time (seconds) of a `bytes`-sized request on
    /// replica `i`: the device's fixed dispatch + transfer pad for the
    /// payload, plus queue depth × per-launch service time. The service
    /// estimate is the device's EWMA gauge, floored at the dispatch cost
    /// (before the first launch retires the EWMA is zero, and a queued
    /// launch can never cost less than its dispatch pad) and at a 1 µs
    /// epsilon — without the epsilon, a pad-less device (`Device::pad ==
    /// None`, the real-hardware case) with a cold EWMA would score 0 at
    /// ANY depth, and a whole burst would pile onto one replica while its
    /// peers idle instead of degrading to least-depth spreading.
    ///
    /// For **batched** pools, `depth` counts *requests* (the occupancy
    /// gauge) while the EWMA measures per-*flush* service, so the product
    /// overestimates drain time by roughly the coalescing factor. The bias
    /// is monotone in load, which is all a ranking policy needs — and it
    /// errs toward spreading, never toward piling onto a busy batcher.
    ///
    /// For **pipeline** pools the dispatch term is the full stage chain —
    /// the entry transfer for the payload plus one zero-byte launch pad
    /// per remaining stage (every stage pays the device's per-command
    /// dispatch cost; only the first moves host bytes) — and the service
    /// term is the end-to-end pipeline EWMA the drivers publish, so depth
    /// × service estimates whole-request drain time, not per-launch time.
    pub fn cost_estimate(&self, i: usize, bytes: usize) -> f64 {
        const SERVICE_EPSILON: f64 = 1e-6;
        let r = &self.replicas[i];
        let stats = r.device.queue.stats();
        let (dispatch, raw_service) = if self.pipeline_stages > 0 {
            let entry = r
                .device
                .pad
                .map(|p| p.transfer_time(bytes).as_secs_f64())
                .unwrap_or(0.0);
            let hop = r
                .device
                .pad
                .map(|p| p.transfer_time(0).as_secs_f64())
                .unwrap_or(0.0);
            (
                entry + hop * self.pipeline_stages.saturating_sub(1) as f64,
                stats.pipe_ewma().as_secs_f64(),
            )
        } else {
            (
                r.device
                    .pad
                    .map(|p| p.transfer_time(bytes).as_secs_f64())
                    .unwrap_or(0.0),
                stats.ewma_service().as_secs_f64(),
            )
        };
        let service = raw_service.max(dispatch).max(SERVICE_EPSILON);
        dispatch + self.depth(i) as f64 * service
    }

    /// Policy pick for affinity-free traffic; only live replicas are
    /// eligible, and no live replica at all is a routed error.
    fn select(&self, bytes: usize) -> Result<usize, String> {
        let n = self.replicas.len();
        match self.policy {
            PlacementPolicy::RoundRobin => {
                // rotate over the LIVE subset: skipping dead slots with a
                // forward probe would hand the successor of every dead
                // replica a double share (dead slot 1 of 3 would map both
                // start%3==1 and ==2 onto replica 2)
                let n_live = self.replicas.iter().filter(|r| r.is_alive()).count();
                if n_live == 0 {
                    return Err("all replicas are down".to_string());
                }
                let pick = self.next_rr.fetch_add(1, Ordering::Relaxed) % n_live;
                let mut first_live = None;
                let mut seen = 0usize;
                for (i, r) in self.replicas.iter().enumerate() {
                    if r.is_alive() {
                        if first_live.is_none() {
                            first_live = Some(i);
                        }
                        if seen == pick {
                            return Ok(i);
                        }
                        seen += 1;
                    }
                }
                // a replica died between the count and the scan; any
                // survivor beats an error
                first_live.ok_or_else(|| "all replicas are down".to_string())
            }
            PlacementPolicy::LeastInflight => {
                let mut best = None;
                let mut best_depth = u64::MAX;
                for i in 0..n {
                    if !self.replicas[i].is_alive() {
                        continue;
                    }
                    let depth = self.depth(i);
                    if depth < best_depth {
                        best = Some(i);
                        best_depth = depth;
                    }
                }
                best.ok_or_else(|| "all replicas are down".to_string())
            }
            PlacementPolicy::CostAware => {
                let mut best = None;
                let mut best_cost = f64::INFINITY;
                for i in 0..n {
                    if !self.replicas[i].is_alive() {
                        continue;
                    }
                    let cost = self.cost_estimate(i, bytes);
                    if cost < best_cost {
                        best = Some(i);
                        best_cost = cost;
                    }
                }
                best.ok_or_else(|| "all replicas are down".to_string())
            }
        }
    }

    /// Policy pick ignoring `Ref` affinity — the migration path's target
    /// choice: when affinity routing failed (refs stranded on a dead,
    /// retired, or absent replica) the dispatcher picks a live replica as
    /// if the request were affinity-free, migrates the refs to its device,
    /// and delegates there.
    pub(crate) fn select_live(&self, bytes: usize) -> Result<usize, String> {
        self.select(bytes)
    }
}

/// A replicated spawn's pieces: the dispatcher (what ordinary callers talk
/// to — `spawn_cl` returns only this) and the [`DevicePool`] behind it, for
/// introspection: per-replica liveness, respawn counts, queue depths. The
/// fault-injection tests and ops tooling use the pool to observe and
/// perturb individual replicas.
pub struct ReplicatedHandle {
    pub actor: ActorRef,
    pub pool: Arc<DevicePool>,
    /// The spawn's admission domain: config, overload/shed/deadline
    /// counters, and the shed registry. Present even for unbounded
    /// spawns (with an all-`None` config) so observability code never
    /// branches.
    pub admission: Arc<Admission>,
}

/// What the dispatcher needs to rebuild a dead replica: recompile the
/// kernel on the replica's device (idempotent on the device queue — an
/// already-compiled executable is reused) and spawn a fresh facade there.
struct Respawner {
    sys: ActorSystem,
    manifest: Manifest,
    timeout: Duration,
    base: KernelSpawn,
    /// Budget + backoff schedule ([`RespawnPolicy::delay_for`]).
    policy: RespawnPolicy,
}

impl Respawner {
    fn respawn(&self, dev: &Arc<Device>) -> Result<ActorRef> {
        let mut cfg = self.base.clone();
        cfg.program = Program::build(
            dev.clone(),
            &self.manifest,
            &[cfg.kernel.as_str()],
            self.timeout,
        )?;
        spawn_on_device(&self.sys, cfg, dev.clone())
    }
}

/// Sent back to the dispatcher by the respawn helper thread. The rebuild
/// (`Program::build` blocks until the device queue reports compilation
/// done — up to `build_timeout`) must NOT run inside the dispatcher's own
/// `Down` handler: that would stall routing to every *healthy* replica
/// for the whole compile, turning one replica death into a full outage
/// instead of N-1 capacity.
struct Respawned {
    /// Replica index the rebuild was for.
    replica: usize,
    /// The fresh facade, or the error to log (the replica stays down).
    facade: Result<ActorRef, String>,
}

/// What the pipeline dispatcher needs to rebuild a dead replica pipeline:
/// recompile EVERY stage's kernel on the replica's device and spawn fresh
/// stage facades plus a fresh driver — a pipeline replica respawns as a
/// unit, never stage-by-stage (a half-new half-old roster would chain
/// continuations across incarnations).
struct PipelineRespawner {
    sys: ActorSystem,
    manifest: Manifest,
    timeout: Duration,
    /// Per-stage base configs (admission stripped, placement pinned) the
    /// rebuild clones and recompiles.
    bases: Vec<KernelSpawn>,
    mode: PipelineMode,
    /// The spawn's admission domain; respawned drivers rejoin it so
    /// deadline counters and the pool bound stay coherent across deaths.
    admission: Arc<Admission>,
    /// Budget + backoff schedule ([`RespawnPolicy::delay_for`]).
    policy: RespawnPolicy,
    label: String,
}

impl PipelineRespawner {
    fn respawn(&self, dev: &Arc<Device>) -> Result<(ActorRef, Vec<ActorRef>)> {
        let mut stage_refs = Vec::with_capacity(self.bases.len());
        for base in &self.bases {
            let mut cfg = base.clone();
            cfg.program = Program::build(
                dev.clone(),
                &self.manifest,
                &[cfg.kernel.as_str()],
                self.timeout,
            )?;
            stage_refs.push(spawn_on_device(&self.sys, cfg, dev.clone())?);
        }
        let driver = spawn_pipeline_driver(
            &self.sys,
            stage_refs.clone(),
            dev.clone(),
            self.mode,
            Some(self.admission.clone()),
            self.label.clone(),
        );
        Ok((driver, stage_refs))
    }
}

/// [`Respawned`]'s pipeline sibling, reported by the `pipeline-respawn`
/// helper thread: a fresh driver plus its stage facades, or the error to
/// log (the replica stays down).
struct PipelineRespawned {
    replica: usize,
    result: Result<(ActorRef, Vec<ActorRef>), String>,
}

/// Affinity + cost inputs of one message: `Ref` device ids and value-
/// payload bytes. The default extraction goes through the clone-free
/// [`RouteScan`](super::arg) — the dispatcher must not deep-copy every
/// payload just to learn there are no refs. Custom `preprocess` functions
/// are called (their extraction defines affinity), which means a `pre`
/// with side effects runs once here and once in the replica; the hook is
/// documented as a pure conversion (Listing 3). `None` when the message
/// does not extract at all (it is still delegated — the replica produces
/// the proper error — but not counted as routed work).
fn route_info(cfg_pre: &Option<PreFn>, msg: &Message) -> Option<RouteScan> {
    let Some(pre) = cfg_pre else {
        return super::arg::route_scan(msg);
    };
    let args = pre(msg)?;
    let mut scan = RouteScan::default();
    for a in &args {
        scan.note_arg(a);
    }
    Some(scan)
}

/// Resolve a replica set's device span against the inventory: every id
/// must exist, no duplicates, non-empty (`what` names the spawn in the
/// errors, e.g. `kernel "vadd_u32"` or `pipeline[sort>count>move]`).
/// Shared by the single-kernel and pipeline replicated spawn paths so the
/// validation rules cannot diverge.
fn resolve_replica_devices(
    mgr: &Manager,
    set: &ReplicaSet,
    what: &str,
) -> Result<Vec<Arc<Device>>> {
    let platform = mgr.try_platform()?;
    let devices: Vec<Arc<Device>> = match &set.devices {
        None => platform.devices.clone(),
        Some(ids) => {
            if ids.is_empty() {
                bail!("{what}: replica device subset is empty");
            }
            let mut picked: Vec<Arc<Device>> = Vec::with_capacity(ids.len());
            for id in ids {
                if picked.iter().any(|d| d.id == *id) {
                    bail!("{what}: device {id} appears twice in the replica subset");
                }
                picked.push(platform.device(*id).cloned().ok_or_else(|| {
                    anyhow!(
                        "{what}: replica subset names device {id}, \
                         which is not in the inventory"
                    )
                })?);
            }
            picked
        }
    };
    if devices.is_empty() {
        bail!("cannot replicate {what}: device inventory is empty");
    }
    Ok(devices)
}

/// Spawn one replica facade per device of the set plus the dispatcher that
/// routes between them (used by `Manager::spawn_cl` /
/// `Manager::spawn_cl_replicated` for [`Placement::Replicated`]).
pub(crate) fn spawn_replicated(
    mgr: &Manager,
    cfg: KernelSpawn,
    set: ReplicaSet,
) -> Result<ReplicatedHandle> {
    let devices = resolve_replica_devices(mgr, &set, &format!("kernel {:?}", cfg.kernel))?;
    let platform = mgr.try_platform()?;
    let sys = mgr.system_handle();
    let timeout = mgr.build_timeout();
    // one admission domain per replicated spawn, shared by the dispatcher
    // (bound + stamping), every replica facade (deadlines, shed registry)
    // and the caller (counters). Installed into the spawn config BEFORE
    // the per-device spawns so batching facades register their windows —
    // and because the respawner's base config is cloned from `cfg`,
    // respawned replicas rejoin the same domain automatically.
    let admission = Arc::new(Admission::new(set.admission));
    let mut cfg = cfg;
    cfg.admission = Some(admission.clone());
    let mut replicas = Vec::with_capacity(devices.len());
    for dev in &devices {
        // reuse the caller's program on its own device; compile the kernel
        // for every other device (the manual multi-device flow of §3.2,
        // automated — same rebuild rule as `Placement::Device`)
        let rcfg = mgr.rebuild_for(cfg.clone(), dev)?;
        let facade = spawn_on_device(&sys, rcfg, dev.clone())?;
        replicas.push(Replica::new(dev.clone(), facade));
    }
    let mut pool = DevicePool::new(replicas, set.policy)?;
    if cfg.batching.is_some() {
        pool.set_batched(true);
    }
    let pool = Arc::new(pool);
    let respawner = match set.respawn {
        RespawnPolicy::Never => None,
        policy => Some(Arc::new(Respawner {
            sys: sys.clone(),
            manifest: platform.manifest.clone(),
            timeout,
            base: cfg.clone(),
            policy,
        })),
    };
    let actor = spawn_dispatcher(
        &sys,
        pool.clone(),
        respawner,
        cfg.pre.clone(),
        admission.clone(),
        set.migrate,
        cfg.kernel,
    );
    Ok(ReplicatedHandle {
        actor,
        pool,
        admission,
    })
}

/// Spawn an entire pipeline per device of the set — every stage facade
/// plus a per-replica [driver](spawn_pipeline_driver) — behind a
/// dispatcher that routes each request to one replica as a unit (used by
/// `Manager::spawn_pipeline` / `Manager::spawn_pipeline_replicated` for
/// [`Placement::Replicated`]). Stage-level `placement`, `admission`, and
/// `batching` knobs are overridden: the unit of placement, admission, and
/// supervision is the pipeline.
pub(crate) fn spawn_pipeline_replicated(
    mgr: &Manager,
    cfg: PipelineSpawn,
    set: ReplicaSet,
) -> Result<ReplicatedHandle> {
    if cfg.stages.is_empty() {
        bail!("pipeline needs at least one stage");
    }
    let label = pipeline_label(&cfg.stages);
    let devices = resolve_replica_devices(mgr, &set, &label)?;
    let platform = mgr.try_platform()?;
    let sys = mgr.system_handle();
    let timeout = mgr.build_timeout();
    // one admission domain per pipeline spawn: the dispatcher gates the
    // pool-wide bound against aggregate driver occupancy, the drivers
    // enforce queue-wait deadlines at the replica boundary. Stage facades
    // never see admission — a stage-level gate would double-charge work
    // the dispatcher already admitted.
    let admission = Arc::new(Admission::new(set.admission));
    let mut bases: Vec<KernelSpawn> = cfg.stages.clone();
    for b in &mut bases {
        b.admission = None;
        b.placement = Placement::Pinned;
    }
    let mut replicas = Vec::with_capacity(devices.len());
    for dev in &devices {
        let mut stage_refs = Vec::with_capacity(bases.len());
        for base in &bases {
            // compile every stage's kernel on THIS replica's device (the
            // manual multi-device flow of §3.2, automated per stage)
            let rcfg = mgr.rebuild_for(base.clone(), dev)?;
            stage_refs.push(spawn_on_device(&sys, rcfg, dev.clone())?);
        }
        let driver = spawn_pipeline_driver(
            &sys,
            stage_refs.clone(),
            dev.clone(),
            cfg.mode,
            Some(admission.clone()),
            label.clone(),
        );
        let replica = Replica::new(dev.clone(), driver);
        replica.set_members(stage_refs);
        replicas.push(replica);
    }
    let mut pool = DevicePool::new(replicas, set.policy)?;
    pool.set_pipeline(bases.len());
    let pool = Arc::new(pool);
    let respawner = match set.respawn {
        RespawnPolicy::Never => None,
        policy => Some(Arc::new(PipelineRespawner {
            sys: sys.clone(),
            manifest: platform.manifest.clone(),
            timeout,
            bases: bases.clone(),
            mode: cfg.mode,
            admission: admission.clone(),
            policy,
            label: label.clone(),
        })),
    };
    let actor = spawn_pipeline_dispatcher(
        &sys,
        pool.clone(),
        respawner,
        bases[0].pre.clone(),
        admission.clone(),
        set.migrate,
        label,
    );
    Ok(ReplicatedHandle {
        actor,
        pool,
        admission,
    })
}

/// Consume one unit of replica `i`'s respawn budget and either start a
/// rebuild or retire the replica permanently. The rebuild runs on a helper
/// thread — it sleeps the policy's exponential backoff, recompiles the
/// program on the replica's device (blocking up to `build_timeout`), and
/// reports back to the dispatcher as a [`Respawned`] message — so routing
/// to the healthy replicas never stalls behind a backoff or a compile (a
/// crash-looping replica must not turn one death into a full outage, and
/// must stop burning compiles once `Limited` says so: the ROADMAP
/// crash-loop item).
fn start_rebuild(
    pool: &Arc<DevicePool>,
    respawner: &Arc<Respawner>,
    kernel: &str,
    i: usize,
    me: ActorRef,
) {
    let dev = pool.replicas()[i].device.clone();
    if pool.replicas()[i].maybe_reset_budget(respawner.policy) {
        log::info!(
            "kernel {kernel}: replica on device {} stayed healthy past the \
             backoff horizon; respawn budget reset",
            dev.id
        );
    }
    let attempt = pool.replicas()[i].note_attempt();
    let Some(backoff) = respawner.policy.delay_for(attempt) else {
        pool.retire(i);
        log::error!(
            "kernel {kernel}: replica on device {} exhausted its respawn budget \
             after {} attempts; permanently dead",
            dev.id,
            attempt.saturating_sub(1)
        );
        return;
    };
    // exactly one rebuild in flight per death: mark_dead cannot match this
    // replica again until install flips it back alive, and a failed
    // rebuild re-enters through the dispatcher's Respawned handler
    let r = respawner.clone();
    let spawned = std::thread::Builder::new()
        .name("replica-respawn".into())
        .spawn(move || {
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            let facade = r.respawn(&dev).map_err(|e| e.to_string());
            me.send_from(None, Message::new(Respawned { replica: i, facade }));
        });
    if let Err(e) = spawned {
        log::error!(
            "kernel {kernel}: could not start respawn thread: {e}; replica stays down"
        );
    }
}

/// Migration fallback when affinity routing failed
/// ([`ReplicaSet::migrate`]): pick a live replica as if the request were
/// affinity-free, device-to-device-copy every `Ref` argument to its device
/// ([`MemRef::migrate_to`](super::mem_ref::MemRef::migrate_to) — the
/// explicit transfer path, pad-priced on both queues), and return the
/// rewritten message plus the target index. `None` when no replica is
/// live or the message's shape is opaque to migration (custom extraction
/// the canonical rewrite cannot see into) — the caller then answers with
/// the original routed error. Each moved buffer bumps the *source*
/// device's migration counter
/// ([`ExecStats::migrations`](crate::runtime::ExecStats)).
fn try_migrate(
    pool: &DevicePool,
    stranded: &[usize],
    bytes: usize,
    msg: &Message,
) -> Option<(usize, Message)> {
    let j = pool.select_live(bytes).ok()?;
    let dst = &pool.replicas()[j].device;
    let moved = super::arg::migrate_message(msg, dst)?;
    log::info!(
        "migrating refs stranded on devices {stranded:?} to device {} and rerouting",
        dst.id
    );
    Some((j, moved))
}

/// The dispatcher: an ordinary event-based actor that routes each message
/// to a replica via [`DevicePool::route`] and delegates it, so the replica
/// answers the original requester directly (no extra hop on the reply
/// path). It monitors every replica facade; `Down` handling is described
/// in the module docs.
fn spawn_dispatcher(
    sys: &ActorSystem,
    pool: Arc<DevicePool>,
    respawner: Option<Arc<Respawner>>,
    pre: Option<PreFn>,
    admission: Arc<Admission>,
    migrate: bool,
    kernel: String,
) -> ActorRef {
    sys.spawn(move |ctx| {
        // supervision: one monitor per replica facade. Down travels on the
        // system-priority lane, so a death is observed ahead of queued
        // ordinary traffic.
        for r in pool.replicas() {
            ctx.monitor(&r.facade());
        }
        let down_pool = pool.clone();
        let down_kernel = kernel.clone();
        let inst_pool = pool.clone();
        let inst_kernel = kernel.clone();
        let inst_respawner = respawner.clone();
        Behavior::new()
            .on(move |ctx, d: &Down| {
                let Some(i) = down_pool.mark_dead(d.source) else {
                    // stale Down for an incarnation already replaced
                    return no_reply();
                };
                let dev = down_pool.replicas()[i].device.clone();
                log::warn!(
                    "kernel {down_kernel}: replica on device {} ({}) died: {:?}; \
                     routed depth drained",
                    dev.id,
                    dev.name,
                    d.reason
                );
                if let Some(r) = &respawner {
                    start_rebuild(&down_pool, r, &down_kernel, i, ctx.me());
                }
                no_reply()
            })
            .on(move |ctx, r: &Respawned| {
                let dev = inst_pool.replicas()[r.replica].device.clone();
                match &r.facade {
                    Ok(f) => {
                        ctx.monitor(f);
                        inst_pool.install(r.replica, f.clone());
                        log::info!(
                            "kernel {inst_kernel}: replica on device {} respawned",
                            dev.id
                        );
                    }
                    Err(e) => match &inst_respawner {
                        // a failed rebuild consumes budget like a death:
                        // `Limited` retries with its backoff until the
                        // budget is spent, then retires the replica.
                        // `Always` keeps its historical behavior — one
                        // failed compile leaves the replica down rather
                        // than looping a deterministic failure forever.
                        Some(rs) if matches!(rs.policy, RespawnPolicy::Limited { .. }) => {
                            log::error!(
                                "kernel {inst_kernel}: respawn on device {} failed: {e}; \
                                 retrying within the respawn budget",
                                dev.id
                            );
                            start_rebuild(&inst_pool, rs, &inst_kernel, r.replica, ctx.me());
                        }
                        _ => {
                            log::error!(
                                "kernel {inst_kernel}: respawn on device {} failed: {e}; \
                                 replica stays down",
                                dev.id
                            );
                        }
                    },
                }
                no_reply()
            })
            .on_any(move |ctx, msg| {
                let info = route_info(&pre, msg);
                let (devs, bytes, extracted) = match &info {
                    Some(s) => (s.devices.as_slice(), s.val_bytes, true),
                    None => (&[][..], 0, false),
                };
                // bounded admission: extracted messages are the ones that
                // become admitted work, so they are the ones the bound
                // gates. Past it, reject with a typed Overloaded error (or
                // shed the stalest queued request under DropOldest) BEFORE
                // routing — an instant error beats unbounded queue growth.
                if extracted {
                    if let Err(e) = admission.try_admit(pool.total_depth(), &kernel) {
                        let promise = ctx.make_promise();
                        promise.deliver_err(e);
                        return Reply::Promised;
                    }
                }
                match pool.route(devs, bytes) {
                    Ok(i) => {
                        if extracted {
                            // count real work toward the routed-depth estimate
                            pool.note_routed(i);
                        }
                        // under a queue-wait deadline, stamp the request
                        // with its admission instant so every later stage
                        // (batch window, facade mailbox) can expire it
                        let outgoing = if admission.cfg().max_queue_wait.is_some() {
                            Message::new(Stamped {
                                at: Instant::now(),
                                inner: msg.clone(),
                            })
                        } else {
                            msg.clone()
                        };
                        ctx.delegate(&pool.replicas()[i].facade(), outgoing);
                    }
                    Err(e) => {
                        // opt-in migration: turn a stranded-Ref routed
                        // error into a reschedule by moving the refs to a
                        // live replica's device and delegating there
                        if migrate && !devs.is_empty() {
                            if let Some((j, moved)) = try_migrate(&pool, devs, bytes, msg) {
                                if extracted {
                                    pool.note_routed(j);
                                }
                                let outgoing = if admission.cfg().max_queue_wait.is_some() {
                                    Message::new(Stamped {
                                        at: Instant::now(),
                                        inner: moved,
                                    })
                                } else {
                                    moved
                                };
                                ctx.delegate(&pool.replicas()[j].facade(), outgoing);
                                return Reply::Promised;
                            }
                        }
                        let promise = ctx.make_promise();
                        promise.deliver_err(ErrorMsg::new(format!("kernel {kernel}: {e}")));
                    }
                }
                Reply::Promised
            })
    })
}

/// Consume one unit of replica `i`'s respawn budget and either start a
/// whole-pipeline rebuild or retire the replica — the pipeline sibling of
/// [`start_rebuild`], with the same budget/backoff/off-thread rules. The
/// helper thread recompiles EVERY stage and reports a
/// [`PipelineRespawned`] back to the dispatcher.
fn start_pipeline_rebuild(
    pool: &Arc<DevicePool>,
    respawner: &Arc<PipelineRespawner>,
    label: &str,
    i: usize,
    me: ActorRef,
) {
    let dev = pool.replicas()[i].device.clone();
    if pool.replicas()[i].maybe_reset_budget(respawner.policy) {
        log::info!(
            "{label}: replica on device {} stayed healthy past the backoff \
             horizon; respawn budget reset",
            dev.id
        );
    }
    let attempt = pool.replicas()[i].note_attempt();
    let Some(backoff) = respawner.policy.delay_for(attempt) else {
        pool.retire(i);
        log::error!(
            "{label}: replica on device {} exhausted its respawn budget \
             after {} attempts; permanently dead",
            dev.id,
            attempt.saturating_sub(1)
        );
        return;
    };
    let r = respawner.clone();
    let spawned = std::thread::Builder::new()
        .name("pipeline-respawn".into())
        .spawn(move || {
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            let result = r.respawn(&dev).map_err(|e| e.to_string());
            me.send_from(None, Message::new(PipelineRespawned { replica: i, result }));
        });
    if let Err(e) = spawned {
        log::error!("{label}: could not start respawn thread: {e}; replica stays down");
    }
}

/// The pipeline dispatcher: routes each request to one replica *pipeline*
/// and delegates it to that replica's driver, so the driver answers the
/// original requester. Differences from the single-kernel
/// [`spawn_dispatcher`]: it monitors the driver AND every stage facade of
/// each replica; `Down` from any of them kills the whole replica pipeline
/// (surviving members are taken down — no half-pipeline may keep serving
/// continuations against dead peers) and a respawn recompiles all stages
/// before reinstalling.
fn spawn_pipeline_dispatcher(
    sys: &ActorSystem,
    pool: Arc<DevicePool>,
    respawner: Option<Arc<PipelineRespawner>>,
    pre: Option<PreFn>,
    admission: Arc<Admission>,
    migrate: bool,
    label: String,
) -> ActorRef {
    sys.spawn(move |ctx| {
        // supervision: one monitor per driver and per stage facade. Down
        // travels on the system-priority lane, ahead of queued traffic.
        for r in pool.replicas() {
            ctx.monitor(&r.facade());
            for s in r.members() {
                ctx.monitor(&s);
            }
        }
        let down_pool = pool.clone();
        let down_label = label.clone();
        let inst_pool = pool.clone();
        let inst_label = label.clone();
        let inst_respawner = respawner.clone();
        Behavior::new()
            .on(move |ctx, d: &Down| {
                let Some(i) = down_pool.mark_dead(d.source) else {
                    // stale Down: an incarnation already replaced, or a
                    // peer this dispatcher itself took down below
                    return no_reply();
                };
                let dev = down_pool.replicas()[i].device.clone();
                log::warn!(
                    "{down_label}: replica on device {} ({}) lost a pipeline \
                     member ({:?}); whole replica pipeline marked dead",
                    dev.id,
                    dev.name,
                    d.reason
                );
                // a pipeline replica dies as a unit: take the surviving
                // members (and the driver) down too. Their Downs come back
                // as stale — mark_dead already flipped the replica dead.
                let peer_exit = |a: &ActorRef| {
                    if a.id() != d.source {
                        a.send_from(None, Message::new(Exit::fault("pipeline peer died")));
                    }
                };
                peer_exit(&down_pool.replicas()[i].facade());
                for s in down_pool.replicas()[i].members() {
                    peer_exit(&s);
                }
                if let Some(rs) = &respawner {
                    start_pipeline_rebuild(&down_pool, rs, &down_label, i, ctx.me());
                }
                no_reply()
            })
            .on(move |ctx, r: &PipelineRespawned| {
                let dev = inst_pool.replicas()[r.replica].device.clone();
                match &r.result {
                    Ok((driver, stage_refs)) => {
                        ctx.monitor(driver);
                        for s in stage_refs {
                            ctx.monitor(s);
                        }
                        // members swap before install flips `alive`, so a
                        // Down racing the install always matches either
                        // the old roster (stale) or the complete new one
                        inst_pool.replicas()[r.replica].set_members(stage_refs.clone());
                        inst_pool.install(r.replica, driver.clone());
                        log::info!(
                            "{inst_label}: replica on device {} respawned \
                             ({} stages recompiled)",
                            dev.id,
                            stage_refs.len()
                        );
                    }
                    Err(e) => match &inst_respawner {
                        // same budget semantics as the single-kernel path:
                        // Limited retries within its budget, Always leaves
                        // the replica down after one failed compile
                        Some(rs) if matches!(rs.policy, RespawnPolicy::Limited { .. }) => {
                            log::error!(
                                "{inst_label}: respawn on device {} failed: {e}; \
                                 retrying within the respawn budget",
                                dev.id
                            );
                            start_pipeline_rebuild(
                                &inst_pool,
                                rs,
                                &inst_label,
                                r.replica,
                                ctx.me(),
                            );
                        }
                        _ => {
                            log::error!(
                                "{inst_label}: respawn on device {} failed: {e}; \
                                 replica stays down",
                                dev.id
                            );
                        }
                    },
                }
                no_reply()
            })
            .on_any(move |ctx, msg| {
                let info = route_info(&pre, msg);
                let (devs, bytes, extracted) = match &info {
                    Some(s) => (s.devices.as_slice(), s.val_bytes, true),
                    None => (&[][..], 0, false),
                };
                // the pool bound gauges aggregate pipeline occupancy: the
                // sum of the drivers' admitted-but-unretired request
                // counts, exactly one unit per request regardless of the
                // stage count
                if extracted {
                    if let Err(e) = admission.try_admit(pool.total_depth(), &label) {
                        let promise = ctx.make_promise();
                        promise.deliver_err(e);
                        return Reply::Promised;
                    }
                }
                match pool.route(devs, bytes) {
                    Ok(i) => {
                        if extracted {
                            pool.note_routed(i);
                        }
                        let outgoing = if admission.cfg().max_queue_wait.is_some() {
                            Message::new(Stamped {
                                at: Instant::now(),
                                inner: msg.clone(),
                            })
                        } else {
                            msg.clone()
                        };
                        ctx.delegate(&pool.replicas()[i].facade(), outgoing);
                    }
                    Err(e) => {
                        if migrate && !devs.is_empty() {
                            if let Some((j, moved)) = try_migrate(&pool, devs, bytes, msg) {
                                if extracted {
                                    pool.note_routed(j);
                                }
                                let outgoing = if admission.cfg().max_queue_wait.is_some() {
                                    Message::new(Stamped {
                                        at: Instant::now(),
                                        inner: moved,
                                    })
                                } else {
                                    moved
                                };
                                ctx.delegate(&pool.replicas()[j].facade(), outgoing);
                                return Reply::Promised;
                            }
                        }
                        let promise = ctx.make_promise();
                        promise.deliver_err(ErrorMsg::new(format!("{label}: {e}")));
                    }
                }
                Reply::Promised
            })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::{ActorSystem, SystemConfig};
    use crate::opencl::device::{DeviceInfo, DeviceKind};
    use crate::runtime::client::PadModel;
    use std::time::Duration;

    fn test_device(id: usize, pad: Option<PadModel>) -> Arc<Device> {
        Device::start(
            id,
            &format!("pool-test-{id}"),
            DeviceKind::Cpu,
            DeviceInfo {
                compute_units: 1,
                max_work_items_per_cu: 1,
            },
            pad,
        )
        .unwrap()
    }

    fn dummy_ref(sys: &ActorSystem) -> ActorRef {
        sys.spawn(|_| Behavior::new().on_any(|_c, _m| Reply::Promised))
    }

    fn pool_of(
        sys: &ActorSystem,
        devices: &[Arc<Device>],
        policy: PlacementPolicy,
    ) -> DevicePool {
        DevicePool::new(
            devices
                .iter()
                .map(|d| Replica::new(d.clone(), dummy_ref(sys)))
                .collect(),
            policy,
        )
        .unwrap()
    }

    #[test]
    fn empty_replica_set_is_an_err() {
        // the fallible-spawn convention: no assert-abort on the spawn path
        let err = match DevicePool::new(Vec::new(), PlacementPolicy::RoundRobin) {
            Ok(_) => panic!("empty pool must be an Err"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("at least one replica"));
    }

    #[test]
    fn round_robin_rotates_and_affinity_overrides() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::RoundRobin);
        assert_eq!(pool.route(&[], 0).unwrap(), 0);
        assert_eq!(pool.route(&[], 0).unwrap(), 1);
        assert_eq!(pool.route(&[], 0).unwrap(), 0);
        // affinity beats rotation
        assert_eq!(pool.route(&[1], 0).unwrap(), 1);
        assert_eq!(pool.route(&[0], 0).unwrap(), 0);
        // unknown device and cross-device refs are routed errors
        assert!(pool.route(&[7], 0).unwrap_err().contains("device 7"));
        assert!(pool
            .route(&[0, 1], 0)
            .unwrap_err()
            .contains("multiple devices"));
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn least_inflight_picks_the_idle_device() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        // device 0 is slow so a submitted launch stays in flight
        let slow = PadModel {
            launch: Duration::from_millis(80),
            bytes_per_sec: 0.0,
            compute_scale: 1.0,
            busy_wait: false,
        };
        let d0 = test_device(0, Some(slow));
        let d1 = test_device(1, None);
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::LeastInflight);
        // both idle: ties resolve to the first replica
        assert_eq!(pool.route(&[], 0).unwrap(), 0);
        // occupy device 0 (the gauge rises at submission time)
        d0.queue
            .compile_emulated("busy", crate::runtime::HostOp::Identity);
        let (bid, _ev) = d0.queue.upload(crate::runtime::HostData::U32(vec![1; 8]));
        let (_out, done) = d0
            .queue
            .execute("busy", vec![bid], crate::runtime::Dtype::U32, vec![]);
        assert!(d0.queue.stats().inflight() >= 1);
        assert_eq!(pool.route(&[], 0).unwrap(), 1, "idle device must win");
        done.wait(Duration::from_secs(30)).unwrap();
        d0.queue.barrier(Duration::from_secs(30)).unwrap();
        // drained: the gauge falls back to zero and ties go first again
        assert_eq!(d0.queue.stats().inflight(), 0);
        assert_eq!(pool.route(&[], 0).unwrap(), 0);
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn routed_depth_spreads_bursts_before_any_launch() {
        // the dispatcher-side estimate: routed-but-not-yet-launched work
        // biases routing away, so a burst spreads at routing time — the
        // device gauge alone would rise only after each replica facade had
        // processed its message
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::LeastInflight);
        let mut picks = Vec::new();
        for _ in 0..6 {
            let i = pool.route(&[], 0).unwrap();
            pool.note_routed(i);
            picks.push(i);
        }
        assert_eq!(picks, vec![0, 1, 0, 1, 0, 1], "burst must alternate");
        assert_eq!(pool.depth(0), 3);
        assert_eq!(pool.depth(1), 3);
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn batched_pools_use_the_published_occupancy_gauge() {
        // a batcher launches once per flush, so per-request routed counts
        // can never reconcile against `launched`; batched pools ignore the
        // routed residue and read the occupancy gauge the batcher
        // publishes into the device's ExecStats instead
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let mut pool =
            pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::LeastInflight);
        pool.set_batched(true);
        for _ in 0..5 {
            pool.note_routed(0);
        }
        assert_eq!(pool.depth(0), 0, "routed residue must not count");
        assert_eq!(pool.route(&[], 0).unwrap(), 0, "idle devices tie to first");
        // a batcher on device 0 publishes three admitted-but-unflushed
        // requests: depth follows the gauge, and selection routes around
        d0.queue.stats().note_batch_admitted(3);
        assert_eq!(pool.depth(0), 3, "occupancy gauge is the depth signal");
        assert_eq!(pool.route(&[], 0).unwrap(), 1, "occupied batcher is avoided");
        // CostAware ranks by the same depth signal
        let mut cost_pool =
            pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::CostAware);
        cost_pool.set_batched(true);
        assert_eq!(cost_pool.route(&[], 64).unwrap(), 1, "cost ranks occupancy");
        d0.queue.stats().note_batch_retired(3);
        assert_eq!(pool.depth(0), 0, "retired requests drain the gauge");
        // saturating drain: an over-release cannot wrap the gauge
        d0.queue.stats().note_batch_retired(100);
        assert_eq!(pool.depth(0), 0);
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn limited_respawn_schedule_backs_off_exponentially_then_gives_up() {
        let p = RespawnPolicy::Limited {
            max: 3,
            backoff: Duration::from_millis(10),
        };
        assert_eq!(p.delay_for(1), Some(Duration::from_millis(10)));
        assert_eq!(p.delay_for(2), Some(Duration::from_millis(20)));
        assert_eq!(p.delay_for(3), Some(Duration::from_millis(40)));
        assert_eq!(p.delay_for(4), None, "budget spent");
        assert_eq!(p.delay_for(u64::MAX), None);
        // Always is the unbounded alias: immediate, forever
        assert_eq!(RespawnPolicy::Always.delay_for(1), Some(Duration::ZERO));
        assert_eq!(
            RespawnPolicy::Always.delay_for(1_000_000),
            Some(Duration::ZERO)
        );
        assert_eq!(RespawnPolicy::Never.delay_for(1), None);
        // a huge attempt count saturates instead of overflowing
        let p = RespawnPolicy::Limited {
            max: u32::MAX,
            backoff: Duration::from_secs(3600),
        };
        assert!(p.delay_for(63).is_some());
    }

    #[test]
    fn retired_replicas_stay_out_of_rotation() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::RoundRobin);
        let id0 = pool.replicas()[0].facade().id();
        pool.mark_dead(id0).unwrap();
        pool.retire(0);
        assert!(pool.replicas()[0].is_retired());
        assert!(!pool.replicas()[0].is_alive());
        for _ in 0..4 {
            assert_eq!(pool.route(&[], 0).unwrap(), 1);
        }
        // attempt accounting is cumulative and observable
        assert_eq!(pool.replicas()[0].respawn_attempts(), 0);
        assert_eq!(pool.replicas()[0].note_attempt(), 1);
        assert_eq!(pool.replicas()[0].respawn_attempts(), 1);
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn dead_replicas_are_skipped_and_drained() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::LeastInflight);
        // pile routed-but-never-launched work onto replica 0, then kill it
        for _ in 0..5 {
            pool.note_routed(0);
        }
        assert_eq!(pool.depth(0), 5);
        let id0 = pool.replicas()[0].facade().id();
        assert_eq!(pool.mark_dead(id0), Some(0));
        assert!(!pool.replicas()[0].is_alive());
        assert_eq!(pool.live_count(), 1);
        // the ROADMAP bug: without the drain these 5 phantom messages
        // would bias routing forever
        assert_eq!(pool.depth(0), 0, "death must drain the routed estimate");
        // selection skips the dead replica (round-robin and depth alike)
        for _ in 0..4 {
            assert_eq!(pool.route(&[], 0).unwrap(), 1);
        }
        // affinity to the dead device is a routed error, not a dead-letter
        let err = pool.route(&[0], 0).unwrap_err();
        assert!(err.contains("down"), "got: {err}");
        // a stale Down for the dead incarnation is ignored
        assert_eq!(pool.mark_dead(id0), None);
        // respawn restores rotation with a clean estimate
        pool.install(0, dummy_ref(&sys));
        assert!(pool.replicas()[0].is_alive());
        assert_eq!(pool.replicas()[0].respawns(), 1);
        assert_eq!(pool.depth(0), 0);
        let picks: Vec<usize> = (0..4).map(|_| pool.route(&[], 0).unwrap()).collect();
        assert!(picks.contains(&0), "respawned replica must serve again");
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn round_robin_splits_evenly_over_survivors() {
        // a dead middle replica must not hand its successor a double
        // share: rotation runs over the live subset, not raw slots
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let devs: Vec<_> = (0..3).map(|i| test_device(i, None)).collect();
        let pool = pool_of(&sys, &devs, PlacementPolicy::RoundRobin);
        let id1 = pool.replicas()[1].facade().id();
        pool.mark_dead(id1).unwrap();
        let mut counts = [0usize; 3];
        for _ in 0..8 {
            counts[pool.route(&[], 0).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0, "dead replica must not serve");
        assert_eq!(counts[0], 4, "survivors split the rotation evenly");
        assert_eq!(counts[2], 4);
        for d in &devs {
            d.queue.stop();
        }
        sys.shutdown();
    }

    #[test]
    fn all_replicas_down_is_a_routed_error() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let pool = pool_of(&sys, &[d0.clone()], PlacementPolicy::RoundRobin);
        let id = pool.replicas()[0].facade().id();
        pool.mark_dead(id).unwrap();
        let err = pool.route(&[], 0).unwrap_err();
        assert!(err.contains("all replicas"), "got: {err}");
        d0.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn cost_aware_steers_by_dispatch_cost_and_depth() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        // device 0: no pad (free dispatch); device 1: Phi-like 30 ms pad
        let phi = PadModel {
            launch: Duration::from_millis(30),
            bytes_per_sec: 0.0,
            compute_scale: 1.0,
            busy_wait: false,
        };
        let d0 = test_device(0, None);
        let d1 = test_device(1, Some(phi));
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::CostAware);
        // small requests: the cheap device wins every time, no matter how
        // the rotation would have gone — the Fig 7b steering
        for _ in 0..6 {
            let i = pool.route(&[], 256).unwrap();
            pool.note_routed(i);
            assert_eq!(i, 0, "cost-aware must avoid the 30 ms dispatch pad");
        }
        assert!(pool.cost_estimate(1, 256) >= Duration::from_millis(30).as_secs_f64());
        // affinity still overrides cost
        assert_eq!(pool.route(&[1], 256).unwrap(), 1);
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn cost_aware_spreads_bursts_across_padless_devices() {
        // two real-hardware-style devices (no pad model, cold EWMA): the
        // service-epsilon floor keeps the depth term alive, so a burst
        // degrades to least-depth spreading instead of piling one replica
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::CostAware);
        let mut picks = Vec::new();
        for _ in 0..6 {
            let i = pool.route(&[], 64).unwrap();
            pool.note_routed(i);
            picks.push(i);
        }
        assert_eq!(picks, vec![0, 1, 0, 1, 0, 1], "burst must alternate");
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn total_depth_sums_the_per_replica_estimates() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let d1 = test_device(1, None);
        let pool = pool_of(&sys, &[d0.clone(), d1.clone()], PlacementPolicy::LeastInflight);
        assert_eq!(pool.total_depth(), 0);
        pool.note_routed(0);
        pool.note_routed(0);
        pool.note_routed(1);
        assert_eq!(pool.total_depth(), 3, "routed-but-unretired work sums");
        d0.queue.stop();
        d1.queue.stop();
        sys.shutdown();
    }

    #[test]
    fn healthy_reset_horizon_is_the_backoff_ladder_with_a_floor() {
        // ms-scale test backoffs floor at 30 s (a test-speed crash loop
        // must never reset itself); big production ladders use their own
        let small = RespawnPolicy::Limited {
            max: 2,
            backoff: Duration::from_millis(1),
        };
        assert_eq!(small.healthy_reset_after(), Some(Duration::from_secs(30)));
        let big = RespawnPolicy::Limited {
            max: 6,
            backoff: Duration::from_secs(1),
        };
        assert_eq!(big.healthy_reset_after(), Some(Duration::from_secs(64)));
        assert_eq!(RespawnPolicy::Never.healthy_reset_after(), None);
        assert_eq!(RespawnPolicy::Always.healthy_reset_after(), None);
    }

    #[test]
    fn respawn_budget_resets_after_a_sustained_healthy_period() {
        let sys = ActorSystem::new(SystemConfig::default().with_threads(2));
        let d0 = test_device(0, None);
        let r = Replica::new(d0.clone(), dummy_ref(&sys));
        let policy = RespawnPolicy::Limited {
            max: 2,
            backoff: Duration::from_millis(1),
        };
        // no attempts spent yet: nothing to reset
        assert!(!r.maybe_reset_budget(policy));
        r.note_attempt();
        r.note_attempt();
        assert_eq!(r.respawn_attempts(), 2);
        // a short healthy period does not reset the budget
        r.note_death();
        assert!(!r.maybe_reset_budget(policy));
        assert_eq!(r.respawn_attempts(), 2);
        // rewind the healthy clock past the 30 s floor and die again:
        // the frozen healthy period now clears the horizon
        *r.healthy_since.lock().unwrap() = Instant::now() - Duration::from_secs(31);
        r.note_death();
        assert!(r.maybe_reset_budget(policy));
        assert_eq!(r.respawn_attempts(), 0, "budget restarts at zero");
        // policies without a budget never reset
        r.note_attempt();
        *r.healthy_since.lock().unwrap() = Instant::now() - Duration::from_secs(31);
        r.note_death();
        assert!(!r.maybe_reset_budget(RespawnPolicy::Always));
        assert_eq!(r.respawn_attempts(), 1);
        d0.queue.stop();
        sys.shutdown();
    }
}
