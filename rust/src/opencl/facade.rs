//! The OpenCL actor: `actor_facade` (paper §3.2).
//!
//! "The facade wraps the kernel execution on OpenCL devices and provides a
//! message passing interface in form of an actor. Whenever a facade
//! receives a message, it creates a command which preserves the original
//! context of a message, schedules execution of the kernel and finally
//! produces a result message."
//!
//! The facade is an ordinary event-based actor — the runtime cannot tell it
//! apart from CPU actors (same [`ActorRef`] handle, monitorable, linkable,
//! composable).
//!
//! Since the placement tier, a facade is no longer bound to the device its
//! program was compiled for at spawn time: [`spawn_on_device`] builds each
//! facade against an explicit device (the replica's), and
//! [`Placement::Replicated`] spawns one such replica per discovered device
//! behind a routing dispatcher (see [`super::placement`]). Val-mode
//! facades can additionally coalesce sub-capacity requests through the
//! adaptive batcher (see [`super::batch`]).

use super::admission::{deadline_error, unstamp, Admission};
use super::arg::{extract_args, ArgValue, Mode};
use super::batch::{spawn_batching_facade, BatchConfig};
use super::command::{Command, CommandStats};
use super::device::Device;
use super::nd_range::NdRange;
use super::placement::Placement;
use super::program::Program;
use crate::actor::{ActorRef, ActorSystem, Behavior, Message, Reply};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Facade-level metrics: launches + cumulative device (enqueue→complete)
/// time, the paper's Fig 5 measurement. A batched facade counts one launch
/// per *flush*, so `launched` is the coalescing metric there.
pub type FacadeStats = CommandStats;

/// Message→argument extraction hook (Listing 3's `preprocess`).
pub type PreFn = Arc<dyn Fn(&Message) -> Option<Vec<ArgValue>> + Send + Sync>;
/// Output→message mapping hook (Listing 3's `postprocess`).
pub type PostFn = Arc<dyn Fn(ArgValue, &Message) -> Message + Send + Sync>;

/// Spawn configuration for an OpenCL actor (the argument list of the
/// paper's `mngr.spawn(...)`, Listings 2/3/5).
#[derive(Clone)]
pub struct KernelSpawn {
    pub program: Arc<Program>,
    pub kernel: String,
    pub range: NdRange,
    /// Per-input boundary mode (`in<T, val|ref>` tags).
    pub in_modes: Vec<Mode>,
    /// Output boundary mode (`out<T, val|ref>`).
    pub out_mode: Mode,
    /// Custom message→arguments extraction (Listing 3's `preprocess`).
    pub pre: Option<PreFn>,
    /// Custom output→message mapping (Listing 3's `postprocess`).
    pub post: Option<PostFn>,
    /// Optional metrics sink.
    pub stats: Option<Arc<FacadeStats>>,
    /// Where the actor runs: pinned to the program's device (default), a
    /// chosen device, or replicated across the inventory.
    pub placement: Placement,
    /// Adaptive request batching for val-mode elementwise kernels: when
    /// set, sub-capacity requests are coalesced into padded launches (one
    /// batcher per replica). See [`BatchConfig`].
    pub batching: Option<BatchConfig>,
    /// Shared admission state (deadline budget, shed registry, outcome
    /// counters). Set by the replicated spawn path from
    /// [`ReplicaSet::admission`](super::placement::ReplicaSet); carried in
    /// the respawn base config so respawned replicas rejoin the same
    /// admission domain.
    pub admission: Option<Arc<Admission>>,
}

impl KernelSpawn {
    pub fn new(program: Arc<Program>, kernel: impl Into<String>) -> KernelSpawn {
        KernelSpawn {
            program,
            kernel: kernel.into(),
            range: NdRange::default(),
            in_modes: Vec::new(),
            out_mode: Mode::Val,
            pre: None,
            post: None,
            stats: None,
            placement: Placement::Pinned,
            batching: None,
            admission: None,
        }
    }

    pub fn range(mut self, range: NdRange) -> Self {
        self.range = range;
        self
    }

    /// All inputs in one mode (common case).
    pub fn inputs(mut self, mode: Mode, n: usize) -> Self {
        self.in_modes = vec![mode; n];
        self
    }

    pub fn input_modes(mut self, modes: &[Mode]) -> Self {
        self.in_modes = modes.to_vec();
        self
    }

    pub fn output(mut self, mode: Mode) -> Self {
        self.out_mode = mode;
        self
    }

    /// Set the placement knob (`Placement::Pinned` is the default).
    pub fn placement(mut self, p: Placement) -> Self {
        self.placement = p;
        self
    }

    /// Enable adaptive request batching (val-mode elementwise kernels).
    pub fn batched(mut self, cfg: BatchConfig) -> Self {
        self.batching = Some(cfg);
        self
    }

    /// Install shared admission state (normally done by the replicated
    /// spawn path; direct pinned spawns may set it for facade-side
    /// deadline enforcement).
    pub fn admission(mut self, a: Arc<Admission>) -> Self {
        self.admission = Some(a);
        self
    }

    pub fn preprocess<F>(mut self, f: F) -> Self
    where
        F: Fn(&Message) -> Option<Vec<ArgValue>> + Send + Sync + 'static,
    {
        self.pre = Some(Arc::new(f));
        self
    }

    pub fn postprocess<F>(mut self, f: F) -> Self
    where
        F: Fn(ArgValue, &Message) -> Message + Send + Sync + 'static,
    {
        self.post = Some(Arc::new(f));
        self
    }

    pub fn with_stats(mut self, stats: Arc<FacadeStats>) -> Self {
        self.stats = Some(stats);
        self
    }

    /// Validate the declaration against the kernel's manifest signature and
    /// the limits of the device the facade will actually run on (the
    /// compile-time checks CAF's template machinery performs in the paper).
    pub fn validate_on(&self, device: &Arc<Device>) -> Result<()> {
        let meta = self.program.kernel(&self.kernel)?;
        if !self.in_modes.is_empty() && self.in_modes.len() != meta.inputs.len() {
            bail!(
                "kernel {} has {} inputs but {} modes were declared",
                self.kernel,
                meta.inputs.len(),
                self.in_modes.len()
            );
        }
        if !self.range.global.is_empty() {
            let max_wg = device.info.max_work_items_per_cu as usize;
            self.range
                .validate(max_wg.max(1024))
                .map_err(|e| anyhow::anyhow!("nd_range: {e}"))?;
        }
        if self.batching.is_some() {
            // the batcher concatenates requests per argument position and
            // scatters output slices back, which is only meaningful for
            // val-mode kernels. Shapes need NOT be uniform: multi-shape
            // kernels batch per shape class, with each request validated
            // as a uniform scale-down of the manifest shape (see
            // `super::batch`) — only empty shapes are unbatchable.
            if self.out_mode != Mode::Val || self.in_modes.iter().any(|m| *m == Mode::Ref) {
                bail!(
                    "kernel {}: batching requires val-mode inputs and output",
                    self.kernel
                );
            }
            let cap = meta.inputs.first().map(|s| s.elems()).unwrap_or(0);
            if cap == 0 {
                bail!("kernel {}: batching needs at least one input", self.kernel);
            }
            if meta.inputs.iter().any(|s| s.elems() == 0) || meta.output.elems() == 0 {
                bail!(
                    "kernel {}: batching requires non-empty input and output shapes",
                    self.kernel
                );
            }
        }
        Ok(())
    }

    /// Validate against the program's own device (the pre-placement check;
    /// kept for callers that never re-place the facade).
    pub fn validate(&self) -> Result<()> {
        let device = self.program.device().clone();
        self.validate_on(&device)
    }
}

/// Spawn the facade actor on the device its program was built for (used by
/// `Manager::spawn_cl` for `Placement::Pinned`).
pub(crate) fn spawn_facade(sys: &ActorSystem, cfg: KernelSpawn) -> Result<ActorRef> {
    let device = cfg.program.device().clone();
    spawn_on_device(sys, cfg, device)
}

/// Spawn a facade bound to an explicit device — the replica entry point of
/// the placement tier. Dispatches to the batching facade when request
/// coalescing was configured.
pub(crate) fn spawn_on_device(
    sys: &ActorSystem,
    cfg: KernelSpawn,
    device: Arc<Device>,
) -> Result<ActorRef> {
    cfg.validate_on(&device)?;
    if cfg.batching.is_some() {
        return spawn_batching_facade(sys, cfg, device);
    }
    let meta = cfg.program.kernel(&cfg.kernel)?.clone();
    Ok(sys.spawn(move |_ctx| {
        let cfg = cfg.clone();
        let meta = meta.clone();
        let device = device.clone();
        Behavior::new().on_any(move |ctx, raw| {
            // routed requests may carry their admission instant; every
            // stage below interprets the inner message
            let (stamp, msg) = unstamp(raw);
            if let (Some(at), Some(budget)) = (
                stamp,
                cfg.admission.as_ref().and_then(|a| a.cfg().max_queue_wait),
            ) {
                let waited = at.elapsed();
                if waited > budget {
                    // expired in the mailbox: fail fast instead of
                    // enqueueing a launch nobody is waiting for
                    device.queue.stats().note_deadline_failed(1);
                    if let Some(a) = &cfg.admission {
                        a.stats
                            .deadline
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    let promise = ctx.make_promise();
                    promise.deliver_err(deadline_error(&cfg.kernel, waited, budget));
                    return Reply::Promised;
                }
            }
            // admission bound for solitary (non-replicated) facades: the
            // replicated dispatcher gates at the pool's total depth before
            // routing, but a pinned/lone facade's mailbox is otherwise
            // unbounded — honor `max_inflight` here against this device's
            // queue depth with the same typed Overloaded rejection.
            // (Replicated replicas skip this: their dispatcher already
            // admitted the request, and double-gating would reject traffic
            // the pool-level bound accepted.)
            if !matches!(cfg.placement, Placement::Replicated(_)) {
                if let Some(a) = &cfg.admission {
                    if let Err(e) = a.try_admit(device.queue.stats().inflight(), &cfg.kernel) {
                        let promise = ctx.make_promise();
                        promise.deliver_err(e);
                        return Reply::Promised;
                    }
                }
            }
            let args = match &cfg.pre {
                Some(pre) => pre(msg),
                None => extract_args(msg),
            };
            let Some(args) = args else {
                // let unmatched messages follow normal actor semantics
                // (stash) by refusing? The facade accepts exactly its kernel
                // signature; everything else is an immediate error, which is
                // more debuggable than a silent stash for device actors.
                let promise = ctx.make_promise();
                promise.deliver_err(crate::actor::ErrorMsg::new(format!(
                    "kernel {} cannot extract arguments from {}",
                    cfg.kernel,
                    msg.type_name()
                )));
                return Reply::Promised;
            };
            let promise = ctx.make_promise();
            Command {
                device: device.clone(),
                meta: meta.clone(),
                args,
                out_mode: cfg.out_mode,
                promise,
                post: cfg.post.clone(),
                incoming: msg.clone(),
                stats: cfg.stats.clone(),
            }
            .enqueue();
            Reply::Promised
        })
    }))
}
