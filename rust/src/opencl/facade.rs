//! The OpenCL actor: `actor_facade` (paper §3.2).
//!
//! "The facade wraps the kernel execution on OpenCL devices and provides a
//! message passing interface in form of an actor. Whenever a facade
//! receives a message, it creates a command which preserves the original
//! context of a message, schedules execution of the kernel and finally
//! produces a result message."
//!
//! The facade is an ordinary event-based actor — the runtime cannot tell it
//! apart from CPU actors (same [`ActorRef`] handle, monitorable, linkable,
//! composable).

use super::arg::{extract_args, ArgValue, Mode};
use super::command::{Command, CommandStats};
use super::nd_range::NdRange;
use super::program::Program;
use crate::actor::{ActorRef, ActorSystem, Behavior, Message, Reply};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Facade-level metrics: launches + cumulative device (enqueue→complete)
/// time, the paper's Fig 5 measurement.
pub type FacadeStats = CommandStats;

type PreFn = Arc<dyn Fn(&Message) -> Option<Vec<ArgValue>> + Send + Sync>;
type PostFn = Arc<dyn Fn(ArgValue, &Message) -> Message + Send + Sync>;

/// Spawn configuration for an OpenCL actor (the argument list of the
/// paper's `mngr.spawn(...)`, Listings 2/3/5).
#[derive(Clone)]
pub struct KernelSpawn {
    pub program: Arc<Program>,
    pub kernel: String,
    pub range: NdRange,
    /// Per-input boundary mode (`in<T, val|ref>` tags).
    pub in_modes: Vec<Mode>,
    /// Output boundary mode (`out<T, val|ref>`).
    pub out_mode: Mode,
    /// Custom message→arguments extraction (Listing 3's `preprocess`).
    pub pre: Option<PreFn>,
    /// Custom output→message mapping (Listing 3's `postprocess`).
    pub post: Option<PostFn>,
    /// Optional metrics sink.
    pub stats: Option<Arc<FacadeStats>>,
}

impl KernelSpawn {
    pub fn new(program: Arc<Program>, kernel: impl Into<String>) -> KernelSpawn {
        KernelSpawn {
            program,
            kernel: kernel.into(),
            range: NdRange::default(),
            in_modes: Vec::new(),
            out_mode: Mode::Val,
            pre: None,
            post: None,
            stats: None,
        }
    }

    pub fn range(mut self, range: NdRange) -> Self {
        self.range = range;
        self
    }

    /// All inputs in one mode (common case).
    pub fn inputs(mut self, mode: Mode, n: usize) -> Self {
        self.in_modes = vec![mode; n];
        self
    }

    pub fn input_modes(mut self, modes: &[Mode]) -> Self {
        self.in_modes = modes.to_vec();
        self
    }

    pub fn output(mut self, mode: Mode) -> Self {
        self.out_mode = mode;
        self
    }

    pub fn preprocess<F>(mut self, f: F) -> Self
    where
        F: Fn(&Message) -> Option<Vec<ArgValue>> + Send + Sync + 'static,
    {
        self.pre = Some(Arc::new(f));
        self
    }

    pub fn postprocess<F>(mut self, f: F) -> Self
    where
        F: Fn(ArgValue, &Message) -> Message + Send + Sync + 'static,
    {
        self.post = Some(Arc::new(f));
        self
    }

    pub fn with_stats(mut self, stats: Arc<FacadeStats>) -> Self {
        self.stats = Some(stats);
        self
    }

    /// Validate the declaration against the kernel's manifest signature and
    /// the device limits (the compile-time checks CAF's template machinery
    /// performs in the paper).
    pub fn validate(&self) -> Result<()> {
        let meta = self.program.kernel(&self.kernel)?;
        if !self.in_modes.is_empty() && self.in_modes.len() != meta.inputs.len() {
            bail!(
                "kernel {} has {} inputs but {} modes were declared",
                self.kernel,
                meta.inputs.len(),
                self.in_modes.len()
            );
        }
        if !self.range.global.is_empty() {
            let max_wg = self.program.device().info.max_work_items_per_cu as usize;
            self.range
                .validate(max_wg.max(1024))
                .map_err(|e| anyhow::anyhow!("nd_range: {e}"))?;
        }
        Ok(())
    }
}

/// Spawn the facade actor (used by `Manager::spawn_cl`).
pub(crate) fn spawn_facade(sys: &ActorSystem, cfg: KernelSpawn) -> Result<ActorRef> {
    cfg.validate()?;
    let meta = cfg.program.kernel(&cfg.kernel)?.clone();
    let device = cfg.program.device().clone();
    Ok(sys.spawn(move |_ctx| {
        let cfg = cfg.clone();
        let meta = meta.clone();
        let device = device.clone();
        Behavior::new().on_any(move |ctx, msg| {
            let args = match &cfg.pre {
                Some(pre) => pre(msg),
                None => extract_args(msg),
            };
            let Some(args) = args else {
                // let unmatched messages follow normal actor semantics
                // (stash) by refusing? The facade accepts exactly its kernel
                // signature; everything else is an immediate error, which is
                // more debuggable than a silent stash for device actors.
                let promise = ctx.make_promise();
                promise.deliver_err(crate::actor::ErrorMsg::new(format!(
                    "kernel {} cannot extract arguments from {}",
                    cfg.kernel,
                    msg.type_name()
                )));
                return Reply::Promised;
            };
            let promise = ctx.make_promise();
            Command {
                device: device.clone(),
                meta: meta.clone(),
                args,
                out_mode: cfg.out_mode,
                promise,
                post: cfg.post.clone(),
                incoming: msg.clone(),
                stats: cfg.stats.clone(),
            }
            .enqueue();
            Reply::Promised
        })
    }))
}
