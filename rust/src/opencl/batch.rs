//! Adaptive request batching for val-mode OpenCL actors.
//!
//! The paper's evaluation found per-request launch overhead dominating
//! sub-second duties (§5: "for sub-second duties, the efficiency of
//! offloading was found to largely differ between devices"). A batching
//! facade amortizes exactly that: requests *smaller than the kernel's
//! declared capacity* are queued and coalesced — within a count window
//! ([`BatchConfig::max_requests`]), a time window
//! ([`BatchConfig::max_delay`]), or until the capacity fills — into one
//! padded launch, submitted through the fused upload+execute queue command
//! ([`DeviceQueue::execute_fused`]) so the whole batch traverses the device
//! command channel once. When the launch completes, each requester receives
//! exactly its slice of the output through its own [`ResponsePromise`].
//!
//! Padding reuses the device cost model's notion of capacity: a batch is
//! zero-padded up to the kernel's manifest shape, so the simulated
//! [`PadModel`](crate::runtime::client::PadModel) charges the same
//! fixed-size transfer the unbatched path pays per request — the win is
//! paying it once per *window* instead of once per message.
//!
//! Batching is restricted to val-mode elementwise kernels (all operands and
//! the output share one shape); `KernelSpawn::validate_on` enforces this at
//! spawn time. A terminating facade flushes its pending window from `Drop`,
//! so shutdown loses no promises: the batch either launches (requesters get
//! their slices) or, if the device queue is already gone, every promise
//! falls back to the broken-promise error.
//!
//! [`DeviceQueue::execute_fused`]: crate::runtime::DeviceQueue::execute_fused
//! [`ResponsePromise`]: crate::actor::request::ResponsePromise

use super::arg::{extract_args, ArgValue};
use super::device::Device;
use super::facade::{FacadeStats, KernelSpawn, PostFn};
use crate::actor::cell::lock;
use crate::actor::request::ResponsePromise;
use crate::actor::{no_reply, ActorRef, ActorSystem, Behavior, ErrorMsg, Message, Reply};
use crate::runtime::artifact::{ArtifactMeta, Dtype};
use crate::runtime::{HostData, UploadSrc};
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching window configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Flush when this many requests are pending (count trigger).
    pub max_requests: usize,
    /// Flush when the oldest pending request has waited this long (time
    /// trigger; armed when a window opens).
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_requests: 16,
            max_delay: Duration::from_millis(1),
        }
    }
}

/// Timer payload arming the time trigger; `gen` identifies the window it
/// was armed for, so a tick that arrives after that window already flushed
/// is a no-op.
#[derive(Clone, Copy, Debug)]
struct FlushTick {
    gen: u64,
}

struct PendingReq {
    promise: ResponsePromise,
    incoming: Message,
    args: Vec<ArgValue>,
    len: usize,
}

struct BatchState {
    device: Arc<Device>,
    meta: ArtifactMeta,
    post: Option<PostFn>,
    stats: Option<Arc<FacadeStats>>,
    cfg: BatchConfig,
    /// Kernel capacity in elements (the manifest shape all operands share).
    capacity: usize,
    pending: Vec<PendingReq>,
    /// Elements accumulated across `pending` (per input).
    elems: usize,
    /// Window generation: bumped on every flush; stale `FlushTick`s
    /// compare unequal and do nothing.
    gen: u64,
}

impl BatchState {
    /// Admit one validated request. Returns `Some(gen)` when the caller
    /// must arm the time trigger for the window this request opened.
    fn admit(
        &mut self,
        args: Vec<ArgValue>,
        promise: ResponsePromise,
        incoming: Message,
    ) -> Option<u64> {
        let k = args[0].len();
        // a request that no longer fits closes the current window first
        if !self.pending.is_empty() && self.elems + k > self.capacity {
            self.flush();
        }
        self.pending.push(PendingReq {
            promise,
            incoming,
            args,
            len: k,
        });
        self.elems += k;
        if self.elems >= self.capacity || self.pending.len() >= self.cfg.max_requests.max(1) {
            self.flush();
            None
        } else if self.pending.len() == 1 {
            Some(self.gen)
        } else {
            None
        }
    }

    /// Coalesce the pending window into one padded fused launch and
    /// scatter the output slices back to the requesters on completion.
    fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.gen = self.gen.wrapping_add(1);
        let reqs = std::mem::take(&mut self.pending);
        self.elems = 0;
        let mut srcs: Vec<UploadSrc> = Vec::with_capacity(self.meta.inputs.len());
        for (j, spec) in self.meta.inputs.iter().enumerate() {
            match spec.dtype {
                Dtype::U32 => {
                    let mut v: Vec<u32> = Vec::with_capacity(spec.elems());
                    for r in &reqs {
                        if let ArgValue::U32(a) = &r.args[j] {
                            v.extend_from_slice(a);
                        }
                    }
                    v.resize(spec.elems(), 0);
                    srcs.push(UploadSrc::Owned(HostData::U32(v)));
                }
                Dtype::F32 => {
                    let mut v: Vec<f32> = Vec::with_capacity(spec.elems());
                    for r in &reqs {
                        if let ArgValue::F32(a) = &r.args[j] {
                            v.extend_from_slice(a);
                        }
                    }
                    v.resize(spec.elems(), 0.0);
                    srcs.push(UploadSrc::Owned(HostData::F32(v)));
                }
            }
        }
        // one command for upload+execute, one for the read-back
        let queue = self.device.queue.clone();
        let (out_id, _done) = queue.execute_fused(&self.meta.name, srcs, self.meta.output.dtype);
        let mut slices = Vec::with_capacity(reqs.len());
        let mut off = 0usize;
        for r in reqs {
            slices.push((r.promise, r.incoming, off, r.len));
            off += r.len;
        }
        let post = self.post.clone();
        let stats = self.stats.clone();
        let t_enqueue = Instant::now();
        let q2 = queue.clone();
        queue.download_with(out_id, move |res| {
            q2.free(out_id);
            if let Some(st) = &stats {
                // one launch per flush: `launched` is the coalescing metric
                st.launched.fetch_add(1, Ordering::Relaxed);
                st.device_ns
                    .fetch_add(t_enqueue.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            match res {
                Ok(host) => {
                    for (promise, incoming, off, len) in slices {
                        if off + len > host.len() {
                            promise.deliver_err(ErrorMsg::new(format!(
                                "batched output of {} elements is shorter than slice {}..{}",
                                host.len(),
                                off,
                                off + len
                            )));
                            continue;
                        }
                        let arg = slice_arg(&host, off, len);
                        let msg = match &post {
                            Some(p) => p(arg, &incoming),
                            None => default_msg(arg),
                        };
                        promise.deliver_msg(msg);
                    }
                }
                Err(e) => {
                    for (promise, _incoming, _off, _len) in slices {
                        promise.deliver_err(ErrorMsg::new(format!("kernel failed: {e}")));
                    }
                }
            }
        });
    }
}

impl Drop for BatchState {
    fn drop(&mut self) {
        // shutdown flush: a terminating facade launches its pending window
        // instead of losing it (see the module docs)
        self.flush();
    }
}

fn slice_arg(host: &HostData, off: usize, len: usize) -> ArgValue {
    match host {
        HostData::U32(v) => ArgValue::U32(Arc::new(v[off..off + len].to_vec())),
        HostData::F32(v) => ArgValue::F32(Arc::new(v[off..off + len].to_vec())),
    }
}

/// Mirror of the unbatched facade's default Val response shape. A shared
/// `Arc` must fall back to *cloning* the contents — `unwrap_or_default()`
/// here would silently deliver an empty vector to the requester whenever
/// another owner still holds the payload.
fn default_msg(arg: ArgValue) -> Message {
    match arg {
        ArgValue::U32(v) => Message::new(Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone())),
        ArgValue::F32(v) => Message::new(Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone())),
        ArgValue::Ref(_) => unreachable!("batcher only produces val outputs"),
    }
}

/// Per-request validation against the kernel signature (the batched analog
/// of `Command::check`): val-only, matching dtypes, one common length per
/// request, within the kernel capacity.
fn check_args(meta: &ArtifactMeta, capacity: usize, args: &[ArgValue]) -> Result<usize, String> {
    if args.len() != meta.inputs.len() {
        return Err(format!(
            "kernel {} expects {} arguments, message carries {}",
            meta.name,
            meta.inputs.len(),
            args.len()
        ));
    }
    // a zero-input signature passes the arity check with an empty list;
    // indexing args[0] would panic the facade (spawn also rejects such
    // manifests, but a direct caller must get a clean Err)
    let Some(first) = args.first() else {
        return Err(format!(
            "kernel {}: batching requires at least one input",
            meta.name
        ));
    };
    let k = first.len();
    for (i, (a, spec)) in args.iter().zip(&meta.inputs).enumerate() {
        if a.is_ref() {
            return Err(format!(
                "kernel {}: batching facade takes val arguments, argument {i} is a mem_ref",
                meta.name
            ));
        }
        if a.dtype() != spec.dtype {
            return Err(format!(
                "kernel {} argument {i}: expected {}, got {}",
                meta.name,
                spec.dtype.name(),
                a.dtype().name()
            ));
        }
        if a.len() != k {
            return Err(format!(
                "kernel {} argument {i}: batch slice of {} elements, argument 0 has {}",
                meta.name,
                a.len(),
                k
            ));
        }
    }
    if k == 0 {
        return Err(format!("kernel {}: empty request", meta.name));
    }
    if k > capacity {
        return Err(format!(
            "kernel {}: request of {k} elements exceeds kernel capacity {capacity}",
            meta.name
        ));
    }
    Ok(k)
}

/// Spawn a batching facade bound to `device` (the replica entry point used
/// by `spawn_on_device` when `KernelSpawn::batching` is set).
pub(crate) fn spawn_batching_facade(
    sys: &ActorSystem,
    cfg: KernelSpawn,
    device: Arc<Device>,
) -> Result<ActorRef> {
    let meta = cfg.program.kernel(&cfg.kernel)?.clone();
    let bcfg = cfg.batching.unwrap_or_default();
    let capacity = meta.inputs[0].elems();
    let pre = cfg.pre.clone();
    let post = cfg.post.clone();
    let stats = cfg.stats.clone();
    let kernel = cfg.kernel.clone();
    Ok(sys.spawn(move |_ctx| {
        let state = Arc::new(Mutex::new(BatchState {
            device,
            meta,
            post,
            stats,
            cfg: bcfg,
            capacity,
            pending: Vec::new(),
            elems: 0,
            gen: 0,
        }));
        let tick_state = state.clone();
        Behavior::new()
            .on(move |_ctx, tick: &FlushTick| {
                let mut st = lock(&tick_state);
                if tick.gen == st.gen {
                    // the window this tick was armed for is still open
                    st.flush();
                }
                no_reply()
            })
            .on_any(move |ctx, msg| {
                let args = match &pre {
                    Some(p) => p(msg),
                    None => extract_args(msg),
                };
                let Some(args) = args else {
                    let promise = ctx.make_promise();
                    promise.deliver_err(ErrorMsg::new(format!(
                        "kernel {kernel} cannot extract arguments from {}",
                        msg.type_name()
                    )));
                    return Reply::Promised;
                };
                let mut st = lock(&state);
                match check_args(&st.meta, st.capacity, &args) {
                    Ok(_k) => {
                        let promise = ctx.make_promise();
                        if let Some(gen) = st.admit(args, promise, msg.clone()) {
                            let delay = st.cfg.max_delay;
                            drop(st);
                            ctx.system().timer().schedule(
                                delay,
                                ctx.me(),
                                Message::new(FlushTick { gen }),
                            );
                        }
                    }
                    Err(e) => {
                        drop(st);
                        let promise = ctx.make_promise();
                        promise.deliver_err(ErrorMsg::new(e));
                    }
                }
                Reply::Promised
            })
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::TensorSpec;
    use std::collections::HashMap;

    fn meta_1in(capacity: usize) -> ArtifactMeta {
        ArtifactMeta {
            name: "t".to_string(),
            file: "emu".to_string(),
            inputs: vec![TensorSpec {
                dtype: Dtype::U32,
                dims: vec![capacity],
            }],
            output: TensorSpec {
                dtype: Dtype::U32,
                dims: vec![capacity],
            },
            extras: HashMap::new(),
        }
    }

    #[test]
    fn check_args_validates_shape_and_mode() {
        let meta = meta_1in(8);
        let ok: Vec<ArgValue> = vec![vec![1u32, 2, 3].into()];
        assert_eq!(check_args(&meta, 8, &ok), Ok(3));
        let too_big: Vec<ArgValue> = vec![vec![0u32; 9].into()];
        assert!(check_args(&meta, 8, &too_big)
            .unwrap_err()
            .contains("exceeds kernel capacity"));
        let wrong_dtype: Vec<ArgValue> = vec![vec![0f32; 4].into()];
        assert!(check_args(&meta, 8, &wrong_dtype)
            .unwrap_err()
            .contains("expected u32"));
        let empty: Vec<ArgValue> = vec![Vec::<u32>::new().into()];
        assert!(check_args(&meta, 8, &empty).unwrap_err().contains("empty"));
        let arity: Vec<ArgValue> = vec![];
        assert!(check_args(&meta, 8, &arity)
            .unwrap_err()
            .contains("expects 1 arguments"));
    }

    #[test]
    fn check_args_zero_input_signature_is_a_clean_err_not_a_panic() {
        // a zero-input manifest entry passes the arity check with an empty
        // argument list; the old code then indexed args[0] and panicked
        // the facade
        let meta = ArtifactMeta {
            name: "zin".to_string(),
            file: "emu".to_string(),
            inputs: vec![],
            output: TensorSpec {
                dtype: Dtype::U32,
                dims: vec![8],
            },
            extras: HashMap::new(),
        };
        let err = check_args(&meta, 8, &[]).unwrap_err();
        assert!(err.contains("at least one input"), "got: {err}");
    }

    #[test]
    fn default_msg_clones_shared_arcs_instead_of_delivering_empty() {
        // regression: a second Arc owner held across delivery made
        // Arc::try_unwrap fail, and unwrap_or_default() then delivered an
        // EMPTY result vector — silent data loss on the reply path
        let payload = Arc::new(vec![7u32, 8, 9]);
        let held = payload.clone(); // second owner across delivery
        let msg = default_msg(ArgValue::U32(payload));
        assert_eq!(
            msg.downcast_ref::<Vec<u32>>(),
            Some(&vec![7, 8, 9]),
            "shared Arc must clone, never deliver empty"
        );
        assert_eq!(*held, vec![7, 8, 9]);

        let payload = Arc::new(vec![1.5f32]);
        let _held = payload.clone();
        let msg = default_msg(ArgValue::F32(payload));
        assert_eq!(msg.downcast_ref::<Vec<f32>>(), Some(&vec![1.5f32]));
    }
}
