//! Adaptive request batching for val-mode OpenCL actors.
//!
//! The paper's evaluation found per-request launch overhead dominating
//! sub-second duties (§5: "for sub-second duties, the efficiency of
//! offloading was found to largely differ between devices"). A batching
//! facade amortizes exactly that: requests *smaller than the kernel's
//! declared capacity* are queued and coalesced — within a count window
//! ([`BatchConfig::max_requests`]), a time window
//! ([`BatchConfig::max_delay`]), or until the capacity fills — into one
//! padded launch, submitted through the fused upload+execute queue command
//! ([`DeviceQueue::execute_fused`]) so the whole batch traverses the device
//! command channel once. When the launch completes, each requester receives
//! exactly its slice of the output through its own [`ResponsePromise`].
//!
//! **Shape classes.** Requests are coalesced per *shape class*: the
//! per-argument element counts plus the dtype signature
//! ([`ClassKey`]). Each class owns its own window with independent
//! count/time/capacity triggers and its own generation counter, so a
//! kernel serving several request shapes — including *multi-shape* kernels
//! whose manifest inputs and output have different element counts —
//! coalesces each shape with its same-shaped peers instead of rejecting
//! them or letting one shape force-flush another's half-filled window.
//! Within a request, every argument must be the same *fraction* of its
//! manifest capacity (a uniform scale-down of the kernel shape); for the
//! common all-same-capacity kernel this degenerates to the old "one common
//! length per request" rule.
//!
//! Padding reuses the device cost model's notion of capacity: a batch is
//! zero-padded up to the kernel's manifest shape (per input), so the
//! simulated [`PadModel`](crate::runtime::client::PadModel) charges the
//! same fixed-size transfer the unbatched path pays per request — the win
//! is paying it once per *window* instead of once per message.
//!
//! **Occupancy gauge.** The batcher publishes its load into the device's
//! [`ExecStats::batch_pending`](crate::runtime::ExecStats) gauge: requests
//! admitted but not yet flushed, plus flushed-but-unretired launches
//! scaled by their request count. The placement tier reads it as the
//! queue-depth signal for batched replicas (`DevicePool::depth`), where
//! the dispatcher's own routed-minus-retired estimate can never reconcile
//! per-request routing against per-flush launches.
//!
//! Batching is restricted to val-mode kernels; `KernelSpawn::validate_on`
//! enforces this at spawn time. A terminating facade flushes every pending
//! window from `Drop`, so shutdown loses no promises: each batch either
//! launches (requesters get their slices) or, if the device queue is
//! already gone, every admitted promise is failed with a routed error —
//! never a silent timeout.
//!
//! [`DeviceQueue::execute_fused`]: crate::runtime::DeviceQueue::execute_fused
//! [`ResponsePromise`]: crate::actor::request::ResponsePromise

use super::admission::{deadline_error, shed_error, unstamp, Admission, ShedQueue};
use super::arg::{extract_args, shape_sig, ArgValue};
use super::device::Device;
use super::facade::{FacadeStats, KernelSpawn, PostFn};
use crate::actor::cell::lock;
use crate::actor::request::ResponsePromise;
use crate::actor::{no_reply, ActorRef, ActorSystem, Behavior, ErrorMsg, Message, Reply};
use crate::runtime::artifact::{ArtifactMeta, Dtype};
use crate::runtime::{HostData, UploadSrc};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching window configuration (per shape class).
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Flush a class when this many of its requests are pending (count
    /// trigger).
    pub max_requests: usize,
    /// Flush a class when its oldest pending request has waited this long
    /// (time trigger; armed when the class's window opens). A zero delay
    /// flushes synchronously inside `admit` — a lone request never pays a
    /// timer hop.
    ///
    /// This is a *ceiling*, not the armed value: the batcher adapts the
    /// actual hold time to each class's measured arrival rate (an EWMA of
    /// its inter-arrival gap). An idle class — next same-class arrival not
    /// expected within the window — flushes synchronously instead of
    /// parking a lone request for the full delay; a hot class holds just
    /// long enough for the count trigger to fill the window, capped here.
    /// When the spawn has an admission deadline, the hold time is further
    /// clamped to 3/4 of `max_queue_wait` so a window always flushes
    /// before its requests start expiring.
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_requests: 16,
            max_delay: Duration::from_millis(1),
        }
    }
}

/// Identity of a sub-batch shape class: the per-argument element counts of
/// one request plus its dtype signature. Requests coalesce iff their keys
/// match — equal keys concatenate per argument position without any
/// cross-request alignment hazard.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub(crate) struct ClassKey {
    /// Element count per argument (manifest input order).
    lens: Vec<usize>,
    /// Dtype per argument. Per-request validation pins these to the
    /// manifest, so all admitted requests of one kernel share them —
    /// carried anyway so class identity is self-contained.
    dtypes: Vec<Dtype>,
}

/// Timer payload arming a class's time trigger; `gen` identifies the
/// window incarnation it was armed for, so a tick that arrives after that
/// window already flushed (count/capacity trigger won the race) is a pure
/// generation compare and a no-op — even when a NEW window of the same
/// class has opened in the meantime, because generations persist per class
/// instead of restarting at zero.
#[derive(Clone, Debug)]
struct FlushTick {
    class: ClassKey,
    gen: u64,
}

struct PendingReq {
    promise: ResponsePromise,
    incoming: Message,
    args: Vec<ArgValue>,
    /// When the dispatcher (or, unrouted, this facade) admitted the
    /// request — the reference point for `max_queue_wait` deadlines and
    /// the DropOldest staleness order.
    admitted: Instant,
}

/// One shape class's open window. Entries persist across flushes (pending
/// cleared, generation bumped) so stale timer ticks can never alias a
/// successor window; the map grows with the number of *distinct shapes
/// seen*, a handful of small vectors per class.
struct Window {
    pending: Vec<PendingReq>,
    /// Elements of argument 0 accumulated across `pending`.
    elems: usize,
    /// Output slice length every request of this class receives.
    out_len: usize,
    /// Window generation: bumped on every flush of THIS class.
    gen: u64,
    /// EWMA of this class's inter-arrival gap in nanoseconds (α = 1/8;
    /// 0 = no gap measured yet). Persists across flushes like `gen`, so
    /// a hot class keeps its rate estimate between windows — this is the
    /// signal the adaptive time trigger holds or releases windows by.
    ewma_gap_ns: u64,
    /// Arrival instant of the class's most recent admit (feeds the EWMA).
    last_admit: Option<Instant>,
}

impl Window {
    fn new(out_len: usize) -> Window {
        Window {
            pending: Vec::new(),
            elems: 0,
            out_len,
            gen: 0,
            ewma_gap_ns: 0,
            last_admit: None,
        }
    }

    /// Fold one arrival into the class's inter-arrival EWMA. A fast
    /// arrival right after an idle spell *resets* the average to the new
    /// gap instead of blending (fast attack): a burst hitting an
    /// idle-marked class must re-open its window on the second request,
    /// not after the 1/8-blend catches up eight launches later.
    fn note_arrival(&mut self, at: Instant, window: Duration) {
        if let Some(prev) = self.last_admit {
            let gap = (at.saturating_duration_since(prev).as_nanos() as u64).max(1);
            let win = window.as_nanos() as u64;
            self.ewma_gap_ns = if self.ewma_gap_ns == 0 {
                gap
            } else if gap <= win && self.ewma_gap_ns > win {
                gap
            } else {
                (self.ewma_gap_ns.saturating_mul(7).saturating_add(gap) / 8).max(1)
            };
        }
        self.last_admit = Some(at);
    }
}

struct BatchState {
    device: Arc<Device>,
    meta: ArtifactMeta,
    post: Option<PostFn>,
    stats: Option<Arc<FacadeStats>>,
    cfg: BatchConfig,
    /// Kernel capacity in elements, per input (the manifest shapes).
    caps: Vec<usize>,
    /// Output capacity in elements.
    out_cap: usize,
    /// Admission control shared with the dispatcher (deadline budget,
    /// shed/deadline counters); `None` for unreplicated or unbounded
    /// spawns.
    admission: Option<Arc<Admission>>,
    /// Per-class sub-batches.
    classes: HashMap<ClassKey, Window>,
}

impl BatchState {
    /// The spawn's per-request queue-wait budget, if any.
    fn queue_wait(&self) -> Option<Duration> {
        self.admission.as_ref().and_then(|a| a.cfg().max_queue_wait)
    }

    /// Adaptive time trigger for one class: the delay to arm for a window
    /// that just opened, derived from the class's measured arrival rate
    /// (see [`BatchConfig::max_delay`]). Zero means "flush synchronously".
    fn effective_delay(&self, key: &ClassKey) -> Duration {
        let base = self.cfg.max_delay;
        let w = match self.classes.get(key) {
            Some(w) => w,
            None => return base,
        };
        let mut delay = if w.ewma_gap_ns == 0 {
            // cold class: no rate estimate yet, hold the configured window
            base
        } else {
            let gap = Duration::from_nanos(w.ewma_gap_ns);
            if gap > base {
                // idle class: the next same-class arrival is not expected
                // within the window — holding buys no coalescing, only
                // latency for the request already here
                Duration::ZERO
            } else {
                // hot class: hold just long enough for the count trigger
                // to fill the window, capped at the configured ceiling
                let remaining =
                    (self.cfg.max_requests.saturating_sub(w.pending.len())).max(1) as u32;
                gap.saturating_mul(remaining).min(base)
            }
        };
        if let Some(budget) = self.queue_wait() {
            // deadline-aware clamp: flush at 3/4 of the queue-wait budget
            // so the window launches before its requests start expiring
            delay = delay.min(budget - budget / 4);
        }
        delay
    }

    /// Admit one validated request into its class's window. Returns
    /// `Some((class, gen, delay))` when the caller must arm the time
    /// trigger for the window this request opened, with the adaptive
    /// delay to arm it at.
    fn admit(
        &mut self,
        key: ClassKey,
        out_len: usize,
        args: Vec<ArgValue>,
        promise: ResponsePromise,
        incoming: Message,
        admitted: Instant,
    ) -> Option<(ClassKey, u64, Duration)> {
        let k0 = key.lens[0];
        let cap0 = self.caps[0];
        // a same-class request that no longer fits closes that class's
        // window first (other classes' windows are untouched — no
        // cross-shape force-flush)
        let needs_preflush = self
            .classes
            .get(&key)
            .map(|w| !w.pending.is_empty() && w.elems + k0 > cap0)
            .unwrap_or(false);
        if needs_preflush {
            self.flush_class(&key);
        }
        // publish occupancy the moment the request is owned by a window;
        // the flush completion (or refusal) retires it
        self.device.queue.stats().note_batch_admitted(1);
        let max_requests = self.cfg.max_requests.max(1);
        let (full, arm) = {
            let w = self
                .classes
                .entry(key.clone())
                .or_insert_with(|| Window::new(out_len));
            w.note_arrival(admitted, self.cfg.max_delay);
            w.pending.push(PendingReq {
                promise,
                incoming,
                args,
                admitted,
            });
            w.elems += k0;
            let full = w.elems >= cap0 || w.pending.len() >= max_requests;
            let arm = if !full && w.pending.len() == 1 {
                Some(w.gen)
            } else {
                None
            };
            (full, arm)
        };
        if full || self.cfg.max_delay.is_zero() {
            // zero max_delay flushes synchronously: the old code still
            // scheduled a FlushTick, so a lone request paid a full timer
            // hop before launching
            self.flush_class(&key);
            return None;
        }
        if let Some(gen) = arm {
            let delay = self.effective_delay(&key);
            if delay.is_zero() {
                // the adaptive trigger sized this class's hold time to
                // nothing (idle class, or a sub-1ns deadline clamp):
                // flush synchronously like an explicit zero max_delay
                self.flush_class(&key);
                return None;
            }
            return Some((key, gen, delay));
        }
        None
    }

    /// Time trigger for one class. Returns whether it flushed; a stale
    /// generation (or an already-empty window) is a pure compare and does
    /// nothing.
    fn on_tick(&mut self, class: &ClassKey, gen: u64) -> bool {
        let live = self
            .classes
            .get(class)
            .map(|w| w.gen == gen && !w.pending.is_empty())
            .unwrap_or(false);
        if live {
            self.flush_class(class);
        }
        live
    }

    /// Coalesce one class's pending window into a padded fused launch and
    /// scatter the output slices back to the requesters on completion.
    fn flush_class(&mut self, key: &ClassKey) {
        let Some(w) = self.classes.get_mut(key) else {
            return;
        };
        if w.pending.is_empty() {
            return;
        }
        w.gen = w.gen.wrapping_add(1);
        let reqs = std::mem::take(&mut w.pending);
        w.elems = 0;
        let out_len = w.out_len;
        self.launch(reqs, out_len);
    }

    /// Flush every class with pending requests (the `Drop` path).
    fn flush_all(&mut self) {
        let keys: Vec<ClassKey> = self
            .classes
            .iter()
            .filter(|(_, w)| !w.pending.is_empty())
            .map(|(k, _)| k.clone())
            .collect();
        for k in keys {
            self.flush_class(&k);
        }
    }

    /// Submit one gathered window: concatenate per argument position, pad
    /// each position to ITS manifest capacity, launch fused, scatter
    /// `out_len`-sized output slices. Every admitted promise resolves
    /// exactly once on every path — completion, kernel failure, or a
    /// closed device queue refusing the submission.
    fn launch(&self, reqs: Vec<PendingReq>, out_len: usize) {
        // deadline fail-fast: a request whose queue wait already exceeded
        // the admission budget gets a deadline error here instead of
        // occupying launch capacity for a reply nobody is waiting for
        let reqs = match self.queue_wait() {
            None => reqs,
            Some(budget) => {
                let mut live = Vec::with_capacity(reqs.len());
                for r in reqs {
                    let waited = r.admitted.elapsed();
                    if waited > budget {
                        self.device.queue.stats().note_batch_retired(1);
                        self.device.queue.stats().note_deadline_failed(1);
                        if let Some(a) = &self.admission {
                            a.stats.deadline.fetch_add(1, Ordering::Relaxed);
                        }
                        r.promise
                            .deliver_err(deadline_error(&self.meta.name, waited, budget));
                    } else {
                        live.push(r);
                    }
                }
                live
            }
        };
        if reqs.is_empty() {
            return;
        }
        let n = reqs.len() as u64;
        let mut srcs: Vec<UploadSrc> = Vec::with_capacity(self.meta.inputs.len());
        for (j, spec) in self.meta.inputs.iter().enumerate() {
            match spec.dtype {
                Dtype::U32 => {
                    let mut v: Vec<u32> = Vec::with_capacity(spec.elems());
                    for r in &reqs {
                        if let ArgValue::U32(a) = &r.args[j] {
                            v.extend_from_slice(a);
                        }
                    }
                    v.resize(spec.elems(), 0);
                    srcs.push(UploadSrc::Owned(HostData::U32(v)));
                }
                Dtype::F32 => {
                    let mut v: Vec<f32> = Vec::with_capacity(spec.elems());
                    for r in &reqs {
                        if let ArgValue::F32(a) = &r.args[j] {
                            v.extend_from_slice(a);
                        }
                    }
                    v.resize(spec.elems(), 0.0);
                    srcs.push(UploadSrc::Owned(HostData::F32(v)));
                }
            }
        }
        // one command for upload+execute, one for the read-back
        let queue = self.device.queue.clone();
        let (out_id, done) = queue.execute_fused(&self.meta.name, srcs, self.meta.output.dtype);
        let mut slices = Vec::with_capacity(reqs.len());
        let mut off = 0usize;
        for r in reqs {
            slices.push((r.promise, r.incoming, off, out_len));
            off += out_len;
        }
        if let Some(Err(e)) = done.poll() {
            // the submission was refused (closed device queue) or failed
            // before we got here: the download below would be refused too,
            // so fail every requester NOW with a real error — the
            // Drop-flush-against-a-stopped-device path must resolve every
            // admitted promise, never leave one to time out
            queue.stats().note_batch_retired(n);
            for (promise, _incoming, _off, _len) in slices {
                promise.deliver_err(ErrorMsg::new(format!("batched launch failed: {e}")));
            }
            return;
        }
        let post = self.post.clone();
        let stats = self.stats.clone();
        let t_enqueue = Instant::now();
        let q2 = queue.clone();
        let enqueued = queue.download_with(out_id, move |res| {
            q2.free(out_id);
            // the window's requests retire from the occupancy gauge as one
            // unit, whether the launch succeeded or not
            q2.stats().note_batch_retired(n);
            if let Some(st) = &stats {
                // one launch per flush: `launched` is the coalescing metric
                st.launched.fetch_add(1, Ordering::Relaxed);
                st.device_ns
                    .fetch_add(t_enqueue.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            match res {
                Ok(host) => {
                    for (promise, incoming, off, len) in slices {
                        if off + len > host.len() {
                            promise.deliver_err(ErrorMsg::new(format!(
                                "batched output of {} elements is shorter than slice {}..{}",
                                host.len(),
                                off,
                                off + len
                            )));
                            continue;
                        }
                        let arg = slice_arg(&host, off, len);
                        let msg = match &post {
                            Some(p) => p(arg, &incoming),
                            None => default_msg(arg),
                        };
                        promise.deliver_msg(msg);
                    }
                }
                Err(e) => {
                    for (promise, _incoming, _off, _len) in slices {
                        promise.deliver_err(ErrorMsg::new(format!("kernel failed: {e}")));
                    }
                }
            }
        });
        if !enqueued {
            // the queue closed between the (accepted) launch and the
            // read-back: the dropped callback already broke its captured
            // promises (each requester got a broken-promise error), so
            // only the occupancy gauge still needs settling here
            queue.stats().note_batch_retired(n);
        }
    }
}

impl Drop for BatchState {
    fn drop(&mut self) {
        // shutdown flush: a terminating facade launches its pending
        // windows instead of losing them (see the module docs)
        self.flush_all();
    }
}

/// The batcher's windows are the admission layer's sheddable queue: under
/// `DropOldest`, the dispatcher asks each registered facade for its
/// stalest queued request and fails the global victim. Implemented on the
/// `Mutex` wrapper so the facade's `Arc<Mutex<BatchState>>` coerces
/// straight into the registry's `Weak<dyn ShedQueue>`.
impl ShedQueue for Mutex<BatchState> {
    fn oldest(&self) -> Option<Instant> {
        let st = lock(self);
        st.classes
            .values()
            .filter_map(|w| w.pending.first().map(|p| p.admitted))
            .min()
    }

    fn shed_oldest(&self) -> bool {
        let mut st = lock(self);
        // windows are FIFO, so each class's stalest entry is pending[0]
        let key = st
            .classes
            .iter()
            .filter(|(_, w)| !w.pending.is_empty())
            .min_by_key(|(_, w)| w.pending[0].admitted)
            .map(|(k, _)| k.clone());
        let Some(key) = key else {
            return false;
        };
        let name = st.meta.name.clone();
        let k0 = key.lens[0];
        let victim = {
            let w = st.classes.get_mut(&key).expect("victim window exists"); // lint-ok: key taken from classes iteration
            let victim = w.pending.remove(0);
            w.elems = w.elems.saturating_sub(k0);
            if w.pending.is_empty() {
                // close the emptied window: an armed tick for this
                // generation must not flush a successor request early
                w.gen = w.gen.wrapping_add(1);
                w.elems = 0;
            }
            victim
        };
        st.device.queue.stats().note_batch_retired(1);
        st.device.queue.stats().note_shed(1);
        drop(st);
        let waited = victim.admitted.elapsed();
        victim.promise.deliver_err(shed_error(&name, waited));
        true
    }
}

fn slice_arg(host: &HostData, off: usize, len: usize) -> ArgValue {
    match host {
        HostData::U32(v) => ArgValue::U32(Arc::new(v[off..off + len].to_vec())),
        HostData::F32(v) => ArgValue::F32(Arc::new(v[off..off + len].to_vec())),
    }
}

/// Mirror of the unbatched facade's default Val response shape. A shared
/// `Arc` must fall back to *cloning* the contents — `unwrap_or_default()`
/// here would silently deliver an empty vector to the requester whenever
/// another owner still holds the payload.
fn default_msg(arg: ArgValue) -> Message {
    match arg {
        ArgValue::U32(v) => Message::new(Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone())),
        ArgValue::F32(v) => Message::new(Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone())),
        ArgValue::Ref(_) => unreachable!("batcher only produces val outputs"),
    }
}

/// Per-input and output capacities of a batching kernel's manifest shape.
/// A zero-input or zero-element manifest is a clean `Err` — the spawn path
/// must never index `meta.inputs[0]` unguarded (the same convention as the
/// `check_args` zero-input fix and the `.first().map(..).unwrap_or(0)`
/// guard in `KernelSpawn::validate_on`).
fn batch_capacities(meta: &ArtifactMeta) -> Result<(Vec<usize>, usize), String> {
    let caps: Vec<usize> = meta.inputs.iter().map(|s| s.elems()).collect();
    if caps.is_empty() {
        return Err(format!(
            "kernel {}: batching requires at least one input",
            meta.name
        ));
    }
    if caps.iter().any(|&c| c == 0) {
        return Err(format!(
            "kernel {}: batching requires non-empty input shapes",
            meta.name
        ));
    }
    let out_cap = meta.output.elems();
    if out_cap == 0 {
        return Err(format!(
            "kernel {}: batching requires a non-empty output shape",
            meta.name
        ));
    }
    Ok((caps, out_cap))
}

/// Per-request validation against the kernel signature (the batched analog
/// of `Command::check`): val-only, matching dtypes, and per-class shape
/// validation — every argument must be the same fraction of its manifest
/// capacity (the request is a uniform scale-down of the kernel shape, so
/// same-class requests concatenate per position and the output slices out
/// evenly). For all-same-capacity kernels this reduces to the old "one
/// common length = capacity shape" rule. Returns the request's
/// [`ClassKey`] and its output slice length.
fn check_args(
    meta: &ArtifactMeta,
    caps: &[usize],
    out_cap: usize,
    args: &[ArgValue],
) -> Result<(ClassKey, usize), String> {
    if args.len() != meta.inputs.len() {
        return Err(format!(
            "kernel {} expects {} arguments, message carries {}",
            meta.name,
            meta.inputs.len(),
            args.len()
        ));
    }
    // a zero-input signature passes the arity check with an empty list;
    // indexing args[0] would panic the facade (spawn also rejects such
    // manifests, but a direct caller must get a clean Err)
    let Some(first) = args.first() else {
        return Err(format!(
            "kernel {}: batching requires at least one input",
            meta.name
        ));
    };
    let k = first.len();
    for (i, (a, spec)) in args.iter().zip(&meta.inputs).enumerate() {
        if a.is_ref() {
            return Err(format!(
                "kernel {}: batching facade takes val arguments, argument {i} is a mem_ref",
                meta.name
            ));
        }
        if a.dtype() != spec.dtype {
            return Err(format!(
                "kernel {} argument {i}: expected {}, got {}",
                meta.name,
                spec.dtype.name(),
                a.dtype().name()
            ));
        }
        // uniform scale-down: len_i / caps[i] == k / caps[0], exactly
        if a.len() * caps[0] != k * caps[i] {
            return Err(format!(
                "kernel {} argument {i}: batch slice of {} elements does not match \
                 argument 0's scale ({k} of capacity {}; argument {i} capacity {})",
                meta.name,
                a.len(),
                caps[0],
                caps[i]
            ));
        }
    }
    if k == 0 {
        return Err(format!("kernel {}: empty request", meta.name));
    }
    if k > caps[0] {
        return Err(format!(
            "kernel {}: request of {k} elements exceeds kernel capacity {}",
            meta.name, caps[0]
        ));
    }
    if (k * out_cap) % caps[0] != 0 || (k * out_cap) / caps[0] == 0 {
        return Err(format!(
            "kernel {}: request of {k} elements does not scale the output shape \
             ({out_cap} elements per {} of input) to a whole slice",
            meta.name, caps[0]
        ));
    }
    let out_len = (k * out_cap) / caps[0];
    let (lens, dtypes) = shape_sig(args);
    Ok((ClassKey { lens, dtypes }, out_len))
}

/// Spawn a batching facade bound to `device` (the replica entry point used
/// by `spawn_on_device` when `KernelSpawn::batching` is set).
pub(crate) fn spawn_batching_facade(
    sys: &ActorSystem,
    cfg: KernelSpawn,
    device: Arc<Device>,
) -> Result<ActorRef> {
    let meta = cfg.program.kernel(&cfg.kernel)?.clone();
    let bcfg = cfg.batching.unwrap_or_default();
    // guard the capacity derivation: a zero-input manifest used to panic
    // here on `meta.inputs[0]` before any validation could reject it
    let (caps, out_cap) = batch_capacities(&meta).map_err(|e| anyhow!(e))?;
    let pre = cfg.pre.clone();
    let post = cfg.post.clone();
    let stats = cfg.stats.clone();
    let kernel = cfg.kernel.clone();
    let admission = cfg.admission.clone();
    Ok(sys.spawn(move |_ctx| {
        let state = Arc::new(Mutex::new(BatchState {
            device,
            meta,
            post,
            stats,
            cfg: bcfg,
            caps,
            out_cap,
            admission: admission.clone(),
            classes: HashMap::new(),
        }));
        if let Some(adm) = &admission {
            // register this facade's windows as a sheddable queue; weakly,
            // so a dying facade unregisters by dropping its state (the
            // respawn base carries the same Admission, so a respawned
            // replica re-registers here too)
            let q: Arc<dyn ShedQueue> = state.clone();
            adm.register(Arc::downgrade(&q));
        }
        let tick_state = state.clone();
        Behavior::new()
            .on(move |_ctx, tick: &FlushTick| {
                // stale ticks are a pure per-class generation compare
                lock(&tick_state).on_tick(&tick.class, tick.gen);
                no_reply()
            })
            .on_any(move |ctx, raw| {
                // routed requests may arrive stamped with their admission
                // instant; every downstream stage interprets the inner
                // message (an unrouted request is admitted here and now)
                let (stamp, msg) = unstamp(raw);
                let admitted = stamp.unwrap_or_else(Instant::now);
                let args = match &pre {
                    Some(p) => p(msg),
                    None => extract_args(msg),
                };
                let Some(args) = args else {
                    let promise = ctx.make_promise();
                    promise.deliver_err(ErrorMsg::new(format!(
                        "kernel {kernel} cannot extract arguments from {}",
                        msg.type_name()
                    )));
                    return Reply::Promised;
                };
                let mut st = lock(&state);
                match check_args(&st.meta, &st.caps, st.out_cap, &args) {
                    Ok((key, out_len)) => {
                        let promise = ctx.make_promise();
                        if let Some(budget) = st.queue_wait() {
                            let waited = admitted.elapsed();
                            if waited > budget {
                                // expired before even reaching a window:
                                // fail fast, and early-flush the class —
                                // anything queued there is older still
                                st.device.queue.stats().note_deadline_failed(1);
                                if let Some(a) = &st.admission {
                                    a.stats.deadline.fetch_add(1, Ordering::Relaxed);
                                }
                                st.flush_class(&key);
                                drop(st);
                                promise.deliver_err(deadline_error(&kernel, waited, budget));
                                return Reply::Promised;
                            }
                        }
                        if let Some((class, gen, delay)) =
                            st.admit(key, out_len, args, promise, msg.clone(), admitted)
                        {
                            drop(st);
                            ctx.system().timer().schedule(
                                delay,
                                ctx.me(),
                                Message::new(FlushTick { class, gen }),
                            );
                        }
                    }
                    Err(e) => {
                        drop(st);
                        let promise = ctx.make_promise();
                        promise.deliver_err(ErrorMsg::new(e));
                    }
                }
                Reply::Promised
            })
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opencl::device::{DeviceInfo, DeviceKind};
    use crate::runtime::artifact::TensorSpec;
    use crate::runtime::HostOp;
    use std::collections::HashMap;

    fn meta_1in(capacity: usize) -> ArtifactMeta {
        ArtifactMeta {
            name: "t".to_string(),
            file: "emu".to_string(),
            inputs: vec![TensorSpec {
                dtype: Dtype::U32,
                dims: vec![capacity],
            }],
            output: TensorSpec {
                dtype: Dtype::U32,
                dims: vec![capacity],
            },
            extras: HashMap::new(),
        }
    }

    /// Kernel with non-uniform shapes: inputs 8 and 4 elements, output 8.
    fn meta_multishape() -> ArtifactMeta {
        ArtifactMeta {
            name: "ms".to_string(),
            file: "emu".to_string(),
            inputs: vec![
                TensorSpec {
                    dtype: Dtype::U32,
                    dims: vec![8],
                },
                TensorSpec {
                    dtype: Dtype::U32,
                    dims: vec![4],
                },
            ],
            output: TensorSpec {
                dtype: Dtype::U32,
                dims: vec![8],
            },
            extras: HashMap::new(),
        }
    }

    fn checked(
        meta: &ArtifactMeta,
        args: &[ArgValue],
    ) -> Result<(ClassKey, usize), String> {
        let (caps, out_cap) = batch_capacities(meta).unwrap();
        check_args(meta, &caps, out_cap, args)
    }

    #[test]
    fn check_args_validates_shape_and_mode() {
        let meta = meta_1in(8);
        let ok: Vec<ArgValue> = vec![vec![1u32, 2, 3].into()];
        let (key, out_len) = checked(&meta, &ok).unwrap();
        assert_eq!(key.lens, vec![3]);
        assert_eq!(key.dtypes, vec![Dtype::U32]);
        assert_eq!(out_len, 3);
        let too_big: Vec<ArgValue> = vec![vec![0u32; 9].into()];
        assert!(checked(&meta, &too_big)
            .unwrap_err()
            .contains("exceeds kernel capacity"));
        let wrong_dtype: Vec<ArgValue> = vec![vec![0f32; 4].into()];
        assert!(checked(&meta, &wrong_dtype)
            .unwrap_err()
            .contains("expected u32"));
        let empty: Vec<ArgValue> = vec![Vec::<u32>::new().into()];
        assert!(checked(&meta, &empty).unwrap_err().contains("empty"));
        let arity: Vec<ArgValue> = vec![];
        assert!(checked(&meta, &arity)
            .unwrap_err()
            .contains("expects 1 arguments"));
    }

    #[test]
    fn check_args_zero_input_signature_is_a_clean_err_not_a_panic() {
        // a zero-input manifest entry passes the arity check with an empty
        // argument list; the old code then indexed args[0] and panicked
        // the facade
        let meta = ArtifactMeta {
            name: "zin".to_string(),
            file: "emu".to_string(),
            inputs: vec![],
            output: TensorSpec {
                dtype: Dtype::U32,
                dims: vec![8],
            },
            extras: HashMap::new(),
        };
        let err = check_args(&meta, &[], 8, &[]).unwrap_err();
        assert!(err.contains("at least one input"), "got: {err}");
    }

    #[test]
    fn batch_capacities_guard_zero_input_manifests() {
        // the spawn-path twin of the check above: capacity derivation used
        // to read meta.inputs[0] and panic before validation could reject
        // the manifest
        let mut meta = meta_1in(8);
        meta.inputs.clear();
        let err = batch_capacities(&meta).unwrap_err();
        assert!(err.contains("at least one input"), "got: {err}");
        let mut meta = meta_1in(8);
        meta.inputs[0].dims = vec![0];
        assert!(batch_capacities(&meta)
            .unwrap_err()
            .contains("non-empty input"));
        let mut meta = meta_1in(8);
        meta.output.dims = vec![0];
        assert!(batch_capacities(&meta)
            .unwrap_err()
            .contains("non-empty output"));
        assert_eq!(batch_capacities(&meta_multishape()).unwrap(), (vec![8, 4], 8));
    }

    #[test]
    fn check_args_classes_multi_shape_requests_by_scale() {
        // inputs 8/4, output 8: a half-scale request is (4, 2) -> out 4
        let meta = meta_multishape();
        let half: Vec<ArgValue> = vec![vec![1u32; 4].into(), vec![2u32; 2].into()];
        let (key, out_len) = checked(&meta, &half).unwrap();
        assert_eq!(key.lens, vec![4, 2]);
        assert_eq!(out_len, 4);
        // quarter scale is a DIFFERENT class
        let quarter: Vec<ArgValue> = vec![vec![1u32; 2].into(), vec![2u32; 1].into()];
        let (qkey, qout) = checked(&meta, &quarter).unwrap();
        assert_ne!(qkey, key);
        assert_eq!(qout, 2);
        // disproportionate arguments are a clean per-request error
        let skewed: Vec<ArgValue> = vec![vec![1u32; 4].into(), vec![2u32; 3].into()];
        assert!(checked(&meta, &skewed).unwrap_err().contains("scale"));
        // a request whose output slice would not divide evenly is rejected
        let meta_odd = ArtifactMeta {
            name: "odd".to_string(),
            file: "emu".to_string(),
            inputs: vec![TensorSpec {
                dtype: Dtype::U32,
                dims: vec![3],
            }],
            output: TensorSpec {
                dtype: Dtype::U32,
                dims: vec![2],
            },
            extras: HashMap::new(),
        };
        let one: Vec<ArgValue> = vec![vec![7u32].into()];
        assert!(checked(&meta_odd, &one)
            .unwrap_err()
            .contains("output shape"));
    }

    #[test]
    fn default_msg_clones_shared_arcs_instead_of_delivering_empty() {
        // regression: a second Arc owner held across delivery made
        // Arc::try_unwrap fail, and unwrap_or_default() then delivered an
        // EMPTY result vector — silent data loss on the reply path
        let payload = Arc::new(vec![7u32, 8, 9]);
        let held = payload.clone(); // second owner across delivery
        let msg = default_msg(ArgValue::U32(payload));
        assert_eq!(
            msg.downcast_ref::<Vec<u32>>(),
            Some(&vec![7, 8, 9]),
            "shared Arc must clone, never deliver empty"
        );
        assert_eq!(*held, vec![7, 8, 9]);

        let payload = Arc::new(vec![1.5f32]);
        let _held = payload.clone();
        let msg = default_msg(ArgValue::F32(payload));
        assert_eq!(msg.downcast_ref::<Vec<f32>>(), Some(&vec![1.5f32]));
    }

    // --- window mechanics against a real device queue -------------------

    fn test_device(meta: &ArtifactMeta) -> Arc<Device> {
        let dev = Device::start(
            0,
            "batch-unit",
            DeviceKind::Cpu,
            DeviceInfo {
                compute_units: 1,
                max_work_items_per_cu: 1,
            },
            None,
        )
        .unwrap();
        dev.queue.compile_emulated(&meta.name, HostOp::Identity);
        dev
    }

    fn state_of(dev: &Arc<Device>, meta: ArtifactMeta, cfg: BatchConfig) -> BatchState {
        let (caps, out_cap) = batch_capacities(&meta).unwrap();
        BatchState {
            device: dev.clone(),
            meta,
            post: None,
            stats: None,
            cfg,
            caps,
            out_cap,
            admission: None,
            classes: HashMap::new(),
        }
    }

    fn req(len: usize) -> Vec<ArgValue> {
        vec![vec![1u32; len].into()]
    }

    fn admit(st: &mut BatchState, len: usize) -> Option<(ClassKey, u64, Duration)> {
        admit_at(st, len, Instant::now())
    }

    fn admit_at(
        st: &mut BatchState,
        len: usize,
        admitted: Instant,
    ) -> Option<(ClassKey, u64, Duration)> {
        let (key, out_len) = check_args(&st.meta, &st.caps, st.out_cap, &req(len)).unwrap();
        st.admit(
            key,
            out_len,
            req(len),
            ResponsePromise::sink(),
            Message::new(()),
            admitted,
        )
    }

    #[test]
    fn stale_tick_for_a_count_flushed_window_is_a_gen_compare_noop() {
        let meta = meta_1in(64);
        let dev = test_device(&meta);
        let mut st = state_of(
            &dev,
            meta,
            BatchConfig {
                max_requests: 2,
                max_delay: Duration::from_secs(600),
            },
        );
        // first request opens the window and asks for a timer at gen 0
        let (key, gen, _) = admit(&mut st, 3).expect("first request arms the trigger");
        assert_eq!(gen, 0);
        // second request count-flushes the window before the tick fires
        assert!(admit(&mut st, 3).is_none());
        // the stale tick is a pure generation compare: no flush, no panic
        assert!(!st.on_tick(&key, 0), "stale tick must be a no-op");
        // a NEW window of the same class persists the class generation, so
        // the old tick cannot alias it either
        let (key2, gen2, _) = admit(&mut st, 3).expect("fresh window arms again");
        assert_eq!(key2, key);
        assert_eq!(gen2, 1, "class generations persist across windows");
        assert!(!st.on_tick(&key, 0), "older-generation tick still a no-op");
        assert!(st.on_tick(&key, 1), "the live generation's tick flushes");
        dev.queue.barrier(Duration::from_secs(30)).unwrap();
        assert_eq!(dev.queue.stats().launched(), 2);
        assert_eq!(
            dev.queue.stats().batch_occupancy(),
            0,
            "retired windows drain the occupancy gauge"
        );
        dev.queue.stop();
    }

    #[test]
    fn zero_max_delay_flushes_synchronously_in_admit() {
        let meta = meta_1in(64);
        let dev = test_device(&meta);
        let mut st = state_of(
            &dev,
            meta,
            BatchConfig {
                max_requests: 1000,
                max_delay: Duration::ZERO,
            },
        );
        // no timer to arm, no pending residue: each admit launches
        assert!(admit(&mut st, 5).is_none(), "zero delay must not arm a timer");
        assert!(admit(&mut st, 5).is_none());
        assert!(st.classes.values().all(|w| w.pending.is_empty()));
        dev.queue.barrier(Duration::from_secs(30)).unwrap();
        assert_eq!(dev.queue.stats().launched(), 2);
        assert_eq!(dev.queue.stats().batch_occupancy(), 0);
        dev.queue.stop();
    }

    #[test]
    fn interleaved_classes_keep_separate_windows() {
        let meta = meta_1in(64);
        let dev = test_device(&meta);
        let mut st = state_of(
            &dev,
            meta,
            BatchConfig {
                max_requests: 2,
                max_delay: Duration::from_secs(600),
            },
        );
        // two classes interleave; neither force-flushes the other
        assert!(admit(&mut st, 3).is_some(), "class A window opens");
        assert!(admit(&mut st, 7).is_some(), "class B window opens");
        assert_eq!(dev.queue.stats().batch_occupancy(), 2);
        assert!(admit(&mut st, 3).is_none(), "class A count-flushes");
        assert!(admit(&mut st, 7).is_none(), "class B count-flushes");
        dev.queue.barrier(Duration::from_secs(30)).unwrap();
        assert_eq!(
            dev.queue.stats().launched(),
            2,
            "two classes -> two fused launches"
        );
        assert_eq!(dev.queue.stats().batch_occupancy(), 0);
        dev.queue.stop();
    }

    #[test]
    fn flush_against_a_closed_queue_drains_occupancy_and_promises() {
        let meta = meta_1in(64);
        let dev = test_device(&meta);
        let mut st = state_of(
            &dev,
            meta,
            BatchConfig {
                max_requests: 1000,
                max_delay: Duration::from_secs(600),
            },
        );
        let (key, _, _) = admit(&mut st, 4).unwrap();
        let _ = admit(&mut st, 4);
        assert_eq!(dev.queue.stats().batch_occupancy(), 2);
        // the device dies before the window flushes
        dev.queue.stop();
        st.flush_class(&key);
        assert_eq!(
            dev.queue.stats().batch_occupancy(),
            0,
            "a refused flush must retire its requests from the gauge"
        );
        assert!(st.classes.values().all(|w| w.pending.is_empty()));
    }

    // --- adaptive delay, deadlines, shedding ----------------------------

    #[test]
    fn note_arrival_tracks_rate_with_fast_attack() {
        let mut w = Window::new(4);
        let win = Duration::from_millis(1);
        let t0 = Instant::now();
        w.note_arrival(t0, win);
        assert_eq!(w.ewma_gap_ns, 0, "first arrival has no gap yet");
        // a 10s gap marks the class idle
        w.note_arrival(t0 + Duration::from_secs(10), win);
        assert_eq!(w.ewma_gap_ns, Duration::from_secs(10).as_nanos() as u64);
        // the first fast arrival after the idle spell RESETS the average
        // (fast attack), instead of blending 7/8 of the 10s in
        w.note_arrival(
            t0 + Duration::from_secs(10) + Duration::from_micros(100),
            win,
        );
        assert_eq!(w.ewma_gap_ns, Duration::from_micros(100).as_nanos() as u64);
        // steady-state arrivals blend at α = 1/8
        w.note_arrival(
            t0 + Duration::from_secs(10) + Duration::from_micros(300),
            win,
        );
        let expected = (100_000u64 * 7 + 200_000) / 8;
        assert_eq!(w.ewma_gap_ns, expected);
    }

    #[test]
    fn effective_delay_adapts_to_class_rate() {
        let meta = meta_1in(1024);
        let dev = test_device(&meta);
        let base = Duration::from_millis(10);
        let mut st = state_of(
            &dev,
            meta,
            BatchConfig {
                max_requests: 8,
                max_delay: base,
            },
        );
        let (key, _, delay) = admit(&mut st, 3).expect("window opens");
        // cold class: no rate estimate, hold the configured ceiling
        assert_eq!(delay, base);
        // idle class (EWMA gap beyond the window): flush synchronously
        st.classes.get_mut(&key).unwrap().ewma_gap_ns =
            Duration::from_millis(50).as_nanos() as u64;
        assert_eq!(st.effective_delay(&key), Duration::ZERO);
        // hot class: hold gap x (max_requests - pending), capped at base
        st.classes.get_mut(&key).unwrap().ewma_gap_ns =
            Duration::from_millis(1).as_nanos() as u64;
        assert_eq!(st.effective_delay(&key), Duration::from_millis(7));
        st.classes.get_mut(&key).unwrap().ewma_gap_ns =
            Duration::from_millis(5).as_nanos() as u64;
        assert_eq!(st.effective_delay(&key), base, "capped at max_delay");
        // deadline clamp: never hold past 3/4 of the queue-wait budget
        st.admission = Some(Arc::new(Admission::new(
            crate::opencl::AdmissionConfig::default().deadline(Duration::from_millis(8)),
        )));
        assert_eq!(st.effective_delay(&key), Duration::from_millis(6));
        dev.queue.stop();
    }

    #[test]
    fn launch_fails_expired_requests_fast_instead_of_launching_them() {
        let meta = meta_1in(64);
        let dev = test_device(&meta);
        let adm = Arc::new(Admission::new(
            crate::opencl::AdmissionConfig::default().deadline(Duration::from_millis(5)),
        ));
        let mut st = state_of(
            &dev,
            meta,
            BatchConfig {
                max_requests: 1000,
                max_delay: Duration::from_secs(600),
            },
        );
        st.admission = Some(adm.clone());
        // one request admitted 10s ago (expired), one fresh
        let stale = Instant::now() - Duration::from_secs(10);
        let (key, _, _) = admit_at(&mut st, 4, stale).expect("window opens");
        let _ = admit(&mut st, 4);
        assert_eq!(dev.queue.stats().batch_occupancy(), 2);
        st.flush_class(&key);
        dev.queue.barrier(Duration::from_secs(30)).unwrap();
        assert_eq!(
            dev.queue.stats().launched(),
            1,
            "the fresh request still launches"
        );
        assert_eq!(dev.queue.stats().deadline_failed(), 1);
        assert_eq!(adm.stats.deadline_count(), 1);
        assert_eq!(dev.queue.stats().batch_occupancy(), 0);
        dev.queue.stop();
    }

    #[test]
    fn shed_oldest_drops_exactly_the_stalest_pending_request() {
        let meta = meta_1in(64);
        let dev = test_device(&meta);
        let st = Arc::new(Mutex::new(state_of(
            &dev,
            meta,
            BatchConfig {
                max_requests: 1000,
                max_delay: Duration::from_secs(600),
            },
        )));
        let t0 = Instant::now() - Duration::from_secs(1);
        {
            let mut s = lock(&st);
            // two classes; the stalest entry sits in the len-7 class
            let _ = admit_at(&mut s, 7, t0);
            let _ = admit_at(&mut s, 3, t0 + Duration::from_millis(10));
            let _ = admit_at(&mut s, 7, t0 + Duration::from_millis(20));
        }
        assert_eq!(dev.queue.stats().batch_occupancy(), 3);
        let q: &Mutex<BatchState> = &st;
        assert_eq!(q.oldest(), Some(t0));
        assert!(q.shed_oldest());
        assert_eq!(dev.queue.stats().batch_occupancy(), 2);
        assert_eq!(dev.queue.stats().shed_count(), 1);
        // the len-7 window lost its head; the next stalest is the len-3
        // entry at t0+10ms
        assert_eq!(q.oldest(), Some(t0 + Duration::from_millis(10)));
        {
            let s = lock(&st);
            let w7 = s.classes.iter().find(|(k, _)| k.lens == vec![7]).unwrap().1;
            assert_eq!(w7.pending.len(), 1);
            assert_eq!(w7.elems, 7, "shed victim's elements leave the window");
        }
        // shedding everything leaves nothing to shed
        assert!(q.shed_oldest());
        assert!(q.shed_oldest());
        assert!(!q.shed_oldest(), "empty windows have no victim");
        assert_eq!(dev.queue.stats().batch_occupancy(), 0);
        dev.queue.stop();
    }
}
