//! A compute device: command queue + capability info (paper Fig 2's
//! `device` class). On this substrate every device is a PJRT CPU client on
//! its own queue thread, optionally shaped by a simulated profile
//! (Tesla / Xeon Phi — DESIGN.md §2).

use crate::runtime::client::PadModel;
use crate::runtime::DeviceQueue;
use anyhow::Result;
use std::sync::Arc;

/// OpenCL's device taxonomy (paper §5.4 distinguishes CPU, GPU and
/// accelerator devices).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    Gpu,
    Accelerator,
}

/// Capability info, used for `nd_range` validation and occupancy estimates
/// (OpenCL exposes these via `clGetDeviceInfo`).
#[derive(Clone, Copy, Debug)]
pub struct DeviceInfo {
    pub compute_units: u32,
    pub max_work_items_per_cu: u32,
}

impl DeviceInfo {
    /// Maximum concurrent work items (paper: "14 compute units that can run
    /// up to 1024 work items each, adding up to 14336").
    pub fn max_concurrency(&self) -> u32 {
        self.compute_units * self.max_work_items_per_cu
    }
}

/// One OpenCL-style device.
pub struct Device {
    pub id: usize,
    pub name: String,
    pub kind: DeviceKind,
    pub info: DeviceInfo,
    pub queue: Arc<DeviceQueue>,
    /// The simulated cost model shaping this device's queue, if any. The
    /// cost-aware placement policy reads it to estimate dispatch+transfer
    /// cost *before* routing (`None` = the real PJRT CPU device, which has
    /// no modeled dispatch pad).
    pub pad: Option<PadModel>,
}

impl Device {
    /// Occupancy published by batching facades bound to this device, in
    /// requests admitted but not yet retired
    /// ([`ExecStats::batch_pending`](crate::runtime::ExecStats)) — the
    /// placement tier's queue-depth signal for batched replicas, whose
    /// per-flush launches make the dispatcher's per-request routed
    /// estimate meaningless.
    pub fn batch_occupancy(&self) -> u64 {
        self.queue.stats().batch_occupancy()
    }

    /// Occupancy published by pipeline drivers bound to this device, in
    /// requests admitted but not yet retired end-to-end
    /// ([`ExecStats::pipe_occupancy`](crate::runtime::ExecStats)) — the
    /// placement tier's queue-depth signal for pipeline replicas, whose
    /// per-stage launches make a per-request routed estimate meaningless
    /// (one admitted request becomes N stage launches).
    pub fn pipe_occupancy(&self) -> u64 {
        self.queue.stats().pipe_occupancy()
    }

    pub(crate) fn start(
        id: usize,
        name: &str,
        kind: DeviceKind,
        info: DeviceInfo,
        pad: Option<PadModel>,
    ) -> Result<Arc<Device>> {
        let queue = DeviceQueue::start(name, pad)?;
        Ok(Arc::new(Device {
            id,
            name: name.to_string(),
            kind,
            info,
            queue,
            pad,
        }))
    }
}

impl std::fmt::Debug for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Device#{} {:?} {} ({} CUs x {} items)",
            self.id, self.kind, self.name, self.info.compute_units, self.info.max_work_items_per_cu
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrency_math() {
        // the paper's Tesla C2075 figures
        let info = DeviceInfo {
            compute_units: 14,
            max_work_items_per_cu: 1024,
        };
        assert_eq!(info.max_concurrency(), 14_336);
    }
}
