//! The OpenCL manager: an actor-system module performing lazy platform
//! discovery and offering the `spawn` interface for OpenCL actors (paper
//! Fig 2's `manager`; loaded via `cfg.load<opencl::manager>()` in
//! Listing 2 — here `Manager::load(&system, specs)`).
//!
//! Discovery is fallible end to end: [`Manager::try_platform`] surfaces a
//! broken artifacts directory or device bring-up failure as an `Err`
//! through every spawn/device accessor instead of aborting the process,
//! and an empty device inventory is a clean error from
//! [`Manager::default_device`] rather than an index panic.

use super::device::Device;
use super::facade::{spawn_facade, spawn_on_device, KernelSpawn};
use super::placement::{self, Placement};
use super::platform::{DeviceSpec, Platform};
use super::program::Program;
use super::stage::{pipeline_label, spawn_pipeline_driver, PipelineSpawn};
use crate::actor::{ActorRef, ActorSystem};
use anyhow::{anyhow, Result};
use once_cell::sync::OnceCell;
use std::sync::Arc;
use std::time::Duration;

const MODULE_KEY: &str = "opencl";

/// The module object stored in the actor system.
pub struct Manager {
    system: ActorSystem,
    specs: Vec<DeviceSpec>,
    platform: OnceCell<Platform>,
}

impl Manager {
    /// Load the module into `system` with the default (host-only) device.
    pub fn load(system: &ActorSystem) -> Arc<Manager> {
        Self::load_with(system, vec![DeviceSpec::host()])
    }

    /// Load with an explicit device inventory (benches add the simulated
    /// Tesla / Xeon Phi devices here).
    pub fn load_with(system: &ActorSystem, specs: Vec<DeviceSpec>) -> Arc<Manager> {
        let mgr = Arc::new(Manager {
            system: system.clone(),
            specs,
            platform: OnceCell::new(),
        });
        system.put_module(MODULE_KEY, mgr.clone());
        mgr
    }

    /// The platform, discovered lazily on first access (paper: "performs
    /// platform discovery lazily on first access"). Discovery failure — a
    /// missing manifest, an unreadable artifacts dir, a device that will
    /// not start — is an `Err` here and through every caller (`spawn_cl`,
    /// `device`, `default_device`), not a process abort.
    pub fn try_platform(&self) -> Result<&Platform> {
        self.platform.get_or_try_init(|| {
            Platform::discover(&self.system.config().artifacts_dir, &self.specs)
        })
    }

    /// Panicking convenience accessor (benches/examples that cannot run
    /// without a platform anyway); fallible callers use [`try_platform`].
    ///
    /// [`try_platform`]: Manager::try_platform
    pub fn platform(&self) -> &Platform {
        self.try_platform()
            .expect("platform discovery failed — run `make artifacts` first") // lint-ok: documented fail-fast API; try_platform() is the fallible twin
    }

    /// Whether discovery already ran (spawn-cost accounting, Fig 4).
    pub fn discovered(&self) -> bool {
        self.platform.get().is_some()
    }

    pub fn device(&self, id: usize) -> Result<Arc<Device>> {
        self.try_platform()?
            .device(id)
            .cloned()
            .ok_or_else(|| anyhow!("no device {id}"))
    }

    /// Default device: the first discovered one (paper §3.6: "the OpenCL
    /// device binding for a kernel defaults to the first discovered
    /// device"). An empty inventory is a clean `Err`.
    pub fn default_device(&self) -> Result<Arc<Device>> {
        self.try_platform()?
            .devices
            .first()
            .cloned()
            .ok_or_else(|| anyhow!("device inventory is empty"))
    }

    /// Program-build deadline (OpenCL's `clBuildProgram` bound), taken from
    /// [`SystemConfig::build_timeout`](crate::actor::SystemConfig).
    pub fn build_timeout(&self) -> Duration {
        self.system.config().build_timeout
    }

    /// Build a program explicitly on a chosen device (the manual flow of
    /// §3.2 for "host systems with multiple co-processors").
    pub fn create_program(&self, device: &Arc<Device>, kernels: &[&str]) -> Result<Arc<Program>> {
        Program::build(
            device.clone(),
            &self.try_platform()?.manifest,
            kernels,
            self.build_timeout(),
        )
    }

    /// One-kernel convenience program on the default device (the simple
    /// `mngr.spawn(source, name, ...)` path of Listing 2).
    pub fn create_kernel_program(&self, kernel: &str) -> Result<Arc<Program>> {
        let dev = self.default_device()?;
        self.create_program(&dev, &[kernel])
    }

    /// Spawn an OpenCL actor. The spawn's [`Placement`] knob decides where
    /// it runs: pinned to its program's device (the paper's behavior and
    /// the default), on an explicitly chosen device, or replicated across
    /// a [`ReplicaSet`](super::placement::ReplicaSet) behind a routing,
    /// replica-supervising dispatcher (`Placement::Replicated` — see
    /// [`super::placement`]).
    pub fn spawn_cl(&self, cfg: KernelSpawn) -> Result<ActorRef> {
        match cfg.placement.clone() {
            Placement::Pinned => spawn_facade(self.system_ref(), cfg),
            Placement::Device(id) => {
                let dev = self.device(id)?;
                let cfg = self.rebuild_for(cfg, &dev)?;
                spawn_on_device(self.system_ref(), cfg, dev)
            }
            Placement::Replicated(set) => {
                Ok(placement::spawn_replicated(self, cfg, set)?.actor)
            }
        }
    }

    /// Replicated spawn that also returns the [`DevicePool`] behind the
    /// dispatcher, for replica introspection — per-replica liveness,
    /// respawn counts, queue-depth estimates ([`ReplicatedHandle`]) —
    /// plus the spawn's [`Admission`] domain (overload/shed/deadline
    /// counters; bounds configured via
    /// [`ReplicaSet::admission`](super::placement::ReplicaSet)). The
    /// spawn must carry `Placement::Replicated`; [`spawn_cl`] is the same
    /// spawn with the pool handle discarded.
    ///
    /// [`DevicePool`]: super::placement::DevicePool
    /// [`ReplicatedHandle`]: super::placement::ReplicatedHandle
    /// [`Admission`]: super::admission::Admission
    /// [`spawn_cl`]: Manager::spawn_cl
    pub fn spawn_cl_replicated(
        &self,
        cfg: KernelSpawn,
    ) -> Result<placement::ReplicatedHandle> {
        match cfg.placement.clone() {
            Placement::Replicated(set) => placement::spawn_replicated(self, cfg, set),
            other => Err(anyhow!(
                "spawn_cl_replicated needs Placement::Replicated, got {other:?}"
            )),
        }
    }

    /// Spawn a placement-tier pipeline (paper §3.5 composed kernels as a
    /// placement unit — see [`PipelineSpawn`]): every stage facade lands
    /// on ONE device plus a per-replica driver that chains the stages with
    /// request continuations, so intermediate `Ref`s never leave that
    /// device. `Placement::Pinned` uses the first stage's program device,
    /// `Placement::Device` an explicit one, and `Placement::Replicated`
    /// spawns the whole pipeline per replica device behind a routing,
    /// whole-pipeline-supervising dispatcher
    /// ([`spawn_pipeline_replicated`](placement::spawn_pipeline_replicated)).
    pub fn spawn_pipeline(&self, cfg: PipelineSpawn) -> Result<ActorRef> {
        match cfg.placement.clone() {
            Placement::Pinned => {
                let dev = cfg
                    .stages
                    .first()
                    .ok_or_else(|| anyhow!("pipeline needs at least one stage"))?
                    .program
                    .device()
                    .clone();
                self.spawn_pipeline_on(cfg, dev)
            }
            Placement::Device(id) => {
                let dev = self.device(id)?;
                self.spawn_pipeline_on(cfg, dev)
            }
            Placement::Replicated(set) => {
                Ok(placement::spawn_pipeline_replicated(self, cfg, set)?.actor)
            }
        }
    }

    /// Replicated pipeline spawn that also returns the pool handle behind
    /// the dispatcher (replica liveness, respawn counts, the stage rosters
    /// via [`Replica::members`](super::placement::Replica::members)) — the
    /// pipeline sibling of [`spawn_cl_replicated`](Self::spawn_cl_replicated).
    /// The spawn must carry `Placement::Replicated`.
    pub fn spawn_pipeline_replicated(
        &self,
        cfg: PipelineSpawn,
    ) -> Result<placement::ReplicatedHandle> {
        match cfg.placement.clone() {
            Placement::Replicated(set) => {
                placement::spawn_pipeline_replicated(self, cfg, set)
            }
            other => Err(anyhow!(
                "spawn_pipeline_replicated needs Placement::Replicated, got {other:?}"
            )),
        }
    }

    /// Single-device pipeline: every stage compiled and spawned on `dev`,
    /// fronted by one driver (no dispatcher — callers talk to the driver
    /// directly). Stage admission is stripped for the same reason as the
    /// replicated path: admission is a pipeline-level concern.
    fn spawn_pipeline_on(
        &self,
        cfg: PipelineSpawn,
        dev: Arc<Device>,
    ) -> Result<ActorRef> {
        if cfg.stages.is_empty() {
            return Err(anyhow!("pipeline needs at least one stage"));
        }
        let label = pipeline_label(&cfg.stages);
        let mut stage_refs = Vec::with_capacity(cfg.stages.len());
        for base in &cfg.stages {
            let mut b = base.clone();
            b.admission = None;
            b.placement = Placement::Pinned;
            let rcfg = self.rebuild_for(b, &dev)?;
            stage_refs.push(spawn_on_device(self.system_ref(), rcfg, dev.clone())?);
        }
        Ok(spawn_pipeline_driver(
            self.system_ref(),
            stage_refs,
            dev,
            cfg.mode,
            None,
            label,
        ))
    }

    /// Recompile the spawn's program on `dev` when it was built for a
    /// different device (a `Command` must be built against the device the
    /// facade actually runs on). Shared with the replicated spawn path, so
    /// `Placement::Device` and `Placement::Replicated` cannot diverge on
    /// the rebuild rule.
    pub(crate) fn rebuild_for(&self, mut cfg: KernelSpawn, dev: &Arc<Device>) -> Result<KernelSpawn> {
        if cfg.program.device().id != dev.id {
            cfg.program = Program::build(
                dev.clone(),
                &self.try_platform()?.manifest,
                &[cfg.kernel.as_str()],
                self.build_timeout(),
            )?;
        }
        Ok(cfg)
    }

    /// Spawn an OpenCL actor for a single kernel on the default device with
    /// uniform input/output modes — the minimal paper-style spawn.
    pub fn spawn_simple(
        &self,
        kernel: &str,
        in_mode: super::arg::Mode,
        out_mode: super::arg::Mode,
    ) -> Result<ActorRef> {
        let program = self.create_kernel_program(kernel)?;
        let n_in = program.kernel(kernel)?.inputs.len();
        self.spawn_cl(
            KernelSpawn::new(program, kernel)
                .inputs(in_mode, n_in)
                .output(out_mode),
        )
    }

    pub(crate) fn system_ref(&self) -> &ActorSystem {
        &self.system
    }

    /// Stop every device queue (called on system shutdown by the owner).
    pub fn stop_devices(&self) {
        if let Some(p) = self.platform.get() {
            p.stop();
        }
    }

    /// One line per device: executions, queue depth, uploads, and
    /// buffer-pool efficiency (hits/misses/returned/evicted). The
    /// measurement methodology is documented in PERF.md.
    pub fn perf_report(&self) -> String {
        let Some(p) = self.platform.get() else {
            return "no devices discovered yet".to_string();
        };
        let mut out = String::new();
        for d in &p.devices {
            let stats = d.queue.stats();
            let (execs, exec_t) = stats.snapshot();
            let (hits, misses, returned, evicted) = stats.pool_snapshot();
            out.push_str(&format!(
                "device {} ({}): execs={} exec_time={:.3}s launched={} inflight={} \
                 uploads={} pool[hits={} misses={} returned={} evicted={}]\n",
                d.id,
                d.name,
                execs,
                exec_t.as_secs_f64(),
                stats.launched(),
                stats.inflight(),
                stats
                    .uploads
                    .load(std::sync::atomic::Ordering::Relaxed),
                hits,
                misses,
                returned,
                evicted
            ));
        }
        out
    }
}

/// `system.opencl_manager()` (paper Listing 2 line 5).
pub trait OpenClSystemExt {
    fn opencl_manager(&self) -> Arc<Manager>;
}

impl OpenClSystemExt for ActorSystem {
    fn opencl_manager(&self) -> Arc<Manager> {
        self.get_module::<Manager>(MODULE_KEY)
            .expect("opencl module not loaded — call Manager::load(&system) first") // lint-ok: documented fail-fast accessor
    }
}
