//! The OpenCL manager: an actor-system module performing lazy platform
//! discovery and offering the `spawn` interface for OpenCL actors (paper
//! Fig 2's `manager`; loaded via `cfg.load<opencl::manager>()` in
//! Listing 2 — here `Manager::load(&system, specs)`).

use super::device::Device;
use super::facade::{spawn_facade, KernelSpawn};
use super::platform::{DeviceSpec, Platform};
use super::program::Program;
use crate::actor::{ActorRef, ActorSystem};
use anyhow::{anyhow, Result};
use once_cell::sync::OnceCell;
use std::sync::Arc;
use std::time::Duration;

const MODULE_KEY: &str = "opencl";
const BUILD_TIMEOUT: Duration = Duration::from_secs(300);

/// The module object stored in the actor system.
pub struct Manager {
    system: ActorSystem,
    specs: Vec<DeviceSpec>,
    platform: OnceCell<Platform>,
}

impl Manager {
    /// Load the module into `system` with the default (host-only) device.
    pub fn load(system: &ActorSystem) -> Arc<Manager> {
        Self::load_with(system, vec![DeviceSpec::host()])
    }

    /// Load with an explicit device inventory (benches add the simulated
    /// Tesla / Xeon Phi devices here).
    pub fn load_with(system: &ActorSystem, specs: Vec<DeviceSpec>) -> Arc<Manager> {
        let mgr = Arc::new(Manager {
            system: system.clone(),
            specs,
            platform: OnceCell::new(),
        });
        system.put_module(MODULE_KEY, mgr.clone());
        mgr
    }

    /// The platform, discovered lazily on first access (paper: "performs
    /// platform discovery lazily on first access").
    pub fn platform(&self) -> &Platform {
        self.platform.get_or_init(|| {
            Platform::discover(&self.system.config().artifacts_dir, &self.specs)
                .expect("platform discovery failed — run `make artifacts` first")
        })
    }

    /// Whether discovery already ran (spawn-cost accounting, Fig 4).
    pub fn discovered(&self) -> bool {
        self.platform.get().is_some()
    }

    pub fn device(&self, id: usize) -> Result<Arc<Device>> {
        self.platform()
            .device(id)
            .cloned()
            .ok_or_else(|| anyhow!("no device {id}"))
    }

    /// Default device: the first discovered one (paper §3.6: "the OpenCL
    /// device binding for a kernel defaults to the first discovered
    /// device").
    pub fn default_device(&self) -> Arc<Device> {
        self.platform().devices[0].clone()
    }

    /// Build a program explicitly on a chosen device (the manual flow of
    /// §3.2 for "host systems with multiple co-processors").
    pub fn create_program(&self, device: &Arc<Device>, kernels: &[&str]) -> Result<Arc<Program>> {
        Program::build(
            device.clone(),
            &self.platform().manifest,
            kernels,
            BUILD_TIMEOUT,
        )
    }

    /// One-kernel convenience program on the default device (the simple
    /// `mngr.spawn(source, name, ...)` path of Listing 2).
    pub fn create_kernel_program(&self, kernel: &str) -> Result<Arc<Program>> {
        let dev = self.default_device();
        self.create_program(&dev, &[kernel])
    }

    /// Spawn an OpenCL actor.
    pub fn spawn_cl(&self, cfg: KernelSpawn) -> Result<ActorRef> {
        spawn_facade(&self.system, cfg)
    }

    /// Spawn an OpenCL actor for a single kernel on the default device with
    /// uniform input/output modes — the minimal paper-style spawn.
    pub fn spawn_simple(
        &self,
        kernel: &str,
        in_mode: super::arg::Mode,
        out_mode: super::arg::Mode,
    ) -> Result<ActorRef> {
        let program = self.create_kernel_program(kernel)?;
        let n_in = program.kernel(kernel)?.inputs.len();
        self.spawn_cl(
            KernelSpawn::new(program, kernel)
                .inputs(in_mode, n_in)
                .output(out_mode),
        )
    }

    pub(crate) fn system_ref(&self) -> &ActorSystem {
        &self.system
    }

    /// Stop every device queue (called on system shutdown by the owner).
    pub fn stop_devices(&self) {
        if let Some(p) = self.platform.get() {
            p.stop();
        }
    }

    /// One line per device: executions, uploads, and buffer-pool
    /// efficiency (hits/misses/returned/evicted). The measurement
    /// methodology is documented in PERF.md.
    pub fn perf_report(&self) -> String {
        let Some(p) = self.platform.get() else {
            return "no devices discovered yet".to_string();
        };
        let mut out = String::new();
        for d in &p.devices {
            let stats = d.queue.stats();
            let (execs, exec_t) = stats.snapshot();
            let (hits, misses, returned, evicted) = stats.pool_snapshot();
            out.push_str(&format!(
                "device {} ({}): execs={} exec_time={:.3}s uploads={} \
                 pool[hits={} misses={} returned={} evicted={}]\n",
                d.id,
                d.name,
                execs,
                exec_t.as_secs_f64(),
                stats
                    .uploads
                    .load(std::sync::atomic::Ordering::Relaxed),
                hits,
                misses,
                returned,
                evicted
            ));
        }
        out
    }
}

/// `system.opencl_manager()` (paper Listing 2 line 5).
pub trait OpenClSystemExt {
    fn opencl_manager(&self) -> Arc<Manager>;
}

impl OpenClSystemExt for ActorSystem {
    fn opencl_manager(&self) -> Arc<Manager> {
        self.get_module::<Manager>(MODULE_KEY)
            .expect("opencl module not loaded — call Manager::load(&system) first")
    }
}
