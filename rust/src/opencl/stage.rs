//! Multi-stage kernel pipelines over device-resident memory (paper §3.5 /
//! §4.1, Listing 5): each stage is an OpenCL actor with Ref-mode operands;
//! the stages are glued with the actor composition operator, so only
//! `MemRef`s travel between them and the data never leaves the device.

use super::arg::{ArgValue, Mode};
use super::facade::KernelSpawn;
use super::manager::Manager;
use super::program::Program;
use crate::actor::{compose, ActorRef, Message};
use anyhow::Result;
use std::sync::Arc;

/// Builder for a composed kernel pipeline
/// (`move_elems * count_elems * prepare` in Listing 5 — stages are given in
/// *flow order* here).
pub struct PipelineBuilder<'m> {
    manager: &'m Manager,
    program: Arc<Program>,
    stages: Vec<KernelSpawn>,
}

impl<'m> PipelineBuilder<'m> {
    pub fn new(manager: &'m Manager, program: Arc<Program>) -> Self {
        PipelineBuilder {
            manager,
            program,
            stages: Vec::new(),
        }
    }

    /// Append a stage with explicit spawn config.
    pub fn stage_cfg(mut self, cfg: KernelSpawn) -> Self {
        self.stages.push(cfg);
        self
    }

    /// Append a stage: first stage accepts host values (`in` = Val), every
    /// stage forwards a device reference (`out` = Ref). End the chain with
    /// [`Self::collect`] to read results back.
    pub fn stage(mut self, kernel: &str) -> Self {
        let n_in = self
            .program
            .kernel(kernel)
            .map(|m| m.inputs.len())
            .unwrap_or(1);
        let in_mode = if self.stages.is_empty() { Mode::Val } else { Mode::Ref };
        self.stages.push(
            KernelSpawn::new(self.program.clone(), kernel)
                .inputs(in_mode, n_in)
                .output(Mode::Ref),
        );
        self
    }

    /// Mark the final stage's output as host values (the last actor "reads
    /// the results back and sends them to the initial requester").
    pub fn collect(mut self) -> Self {
        if let Some(last) = self.stages.last_mut() {
            last.out_mode = Mode::Val;
        }
        self
    }

    /// Spawn every stage actor and compose them; returns (pipeline,
    /// stage actors in flow order). An empty pipeline is an `Err` (the
    /// fallible-spawn convention — `try_platform` / `default_device`
    /// surface errors instead of aborting the process).
    pub fn build(self) -> Result<(ActorRef, Vec<ActorRef>)> {
        if self.stages.is_empty() {
            anyhow::bail!("pipeline needs at least one stage");
        }
        let sys = self.manager_system();
        let mut actors = Vec::new();
        for cfg in self.stages {
            actors.push(self.manager.spawn_cl(cfg)?);
        }
        let mut it = actors.iter().cloned();
        let first = it.next().expect("non-empty checked above"); // lint-ok: guarded by emptiness check
        let composed = it.fold(first, |acc, next| compose(&sys, next, acc));
        Ok((composed, actors))
    }

    fn manager_system(&self) -> crate::actor::ActorSystem {
        // the manager spawns its facades on its owning system; reuse it via
        // a tiny probe spawn-free accessor
        self.manager.system_handle()
    }
}

impl Manager {
    pub(crate) fn system_handle(&self) -> crate::actor::ActorSystem {
        // Manager stores the system; expose internally for the builder.
        self.system_ref().clone()
    }
}

/// Postprocess helper: fan a stage's `MemRef` output into a tuple with a
/// previously captured reference (stages whose successor needs several
/// operands, e.g. `lut(fillslit, sorted)` in the WAH pipeline).
pub fn post_pair_with(extra: MemRefSlot) -> impl Fn(ArgValue, &Message) -> Message + Send + Sync {
    move |out, _inc| match (&out, extra.get()) {
        (ArgValue::Ref(r), Some(e)) => Message::new(vec![
            ArgValue::Ref(r.clone()),
            ArgValue::Ref(e),
        ]),
        _ => Message::new(out),
    }
}

/// A shared, set-once slot for plumbing a `MemRef` across stage boundaries
/// (the paper does this with custom pre/post functions).
#[derive(Clone, Default)]
pub struct MemRefSlot {
    inner: Arc<std::sync::Mutex<Option<super::mem_ref::MemRef>>>,
}

impl MemRefSlot {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, r: super::mem_ref::MemRef) {
        *self.inner.lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
    }

    pub fn get(&self) -> Option<super::mem_ref::MemRef> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    pub fn take(&self) -> Option<super::mem_ref::MemRef> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).take()
    }
}
