//! Multi-stage kernel pipelines over device-resident memory (paper §3.5 /
//! §4.1, Listing 5): each stage is an OpenCL actor with Ref-mode operands;
//! only `MemRef`s travel between stages, so the data never leaves the
//! device.
//!
//! Two generations live here:
//!
//! * [`PipelineBuilder`] — the paper's original shape: spawn each stage,
//!   glue them with the actor composition operator
//!   ([`compose`](crate::actor::compose)). Lock-step by construction (each
//!   composed hop serves one request at a time) and invisible to the
//!   placement tier — the composed actor is pinned wherever its stages
//!   were spawned. Kept as the composed baseline the pipeline benches
//!   compare against.
//! * [`PipelineSpawn`] — the placement-tier citizen: a stage list of
//!   [`KernelSpawn`]s routed *as a unit* by
//!   [`Manager::spawn_pipeline`](super::manager::Manager::spawn_pipeline).
//!   Under [`Placement::Replicated`] the whole pipeline is compiled and
//!   spawned once per replica device behind the ordinary dispatcher
//!   `ActorRef`, so a request routes once and every stage's `Ref` stays on
//!   the chosen replica's device. Each replica fronts its stages with a
//!   *driver* actor ([`spawn_pipeline_driver`]) that chains the stages
//!   with request continuations instead of composed actors — under the
//!   default [`PipelineMode::Interleaved`] the driver keeps every accepted
//!   request in flight at once, so independent stages of *different*
//!   requests interleave on one device (the dynamic data-rate scheduling
//!   of Boutellier & Hautala), while [`PipelineMode::LockStep`] reproduces
//!   the composed one-at-a-time behavior for comparison. The driver
//!   publishes its occupancy into the device's
//!   [`ExecStats::pipe_occupancy`](crate::runtime::ExecStats) gauge and
//!   its end-to-end latency into the pipeline EWMA, which is what the
//!   cost/depth steering reads for pipeline pools.

use super::admission::{deadline_error, unstamp, Admission};
use super::arg::{extract_args, ArgValue, Mode};
use super::device::Device;
use super::facade::KernelSpawn;
use super::manager::Manager;
use super::placement::Placement;
use super::program::Program;
use crate::actor::request::ResponsePromise;
use crate::actor::{compose, ActorRef, ActorSystem, Behavior, Ctx, ErrorMsg, Message, Reply};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Builder for a composed kernel pipeline
/// (`move_elems * count_elems * prepare` in Listing 5 — stages are given in
/// *flow order* here).
pub struct PipelineBuilder<'m> {
    manager: &'m Manager,
    program: Arc<Program>,
    stages: Vec<KernelSpawn>,
}

impl<'m> PipelineBuilder<'m> {
    pub fn new(manager: &'m Manager, program: Arc<Program>) -> Self {
        PipelineBuilder {
            manager,
            program,
            stages: Vec::new(),
        }
    }

    /// Append a stage with explicit spawn config.
    pub fn stage_cfg(mut self, cfg: KernelSpawn) -> Self {
        self.stages.push(cfg);
        self
    }

    /// Append a stage: first stage accepts host values (`in` = Val), every
    /// stage forwards a device reference (`out` = Ref). End the chain with
    /// [`Self::collect`] to read results back.
    pub fn stage(mut self, kernel: &str) -> Self {
        let n_in = self
            .program
            .kernel(kernel)
            .map(|m| m.inputs.len())
            .unwrap_or(1);
        let in_mode = if self.stages.is_empty() { Mode::Val } else { Mode::Ref };
        self.stages.push(
            KernelSpawn::new(self.program.clone(), kernel)
                .inputs(in_mode, n_in)
                .output(Mode::Ref),
        );
        self
    }

    /// Mark the final stage's output as host values (the last actor "reads
    /// the results back and sends them to the initial requester").
    pub fn collect(mut self) -> Self {
        if let Some(last) = self.stages.last_mut() {
            last.out_mode = Mode::Val;
        }
        self
    }

    /// Spawn every stage actor and compose them; returns (pipeline,
    /// stage actors in flow order). An empty pipeline is an `Err` (the
    /// fallible-spawn convention — `try_platform` / `default_device`
    /// surface errors instead of aborting the process).
    pub fn build(self) -> Result<(ActorRef, Vec<ActorRef>)> {
        if self.stages.is_empty() {
            anyhow::bail!("pipeline needs at least one stage");
        }
        let sys = self.manager_system();
        let mut actors = Vec::new();
        for cfg in self.stages {
            actors.push(self.manager.spawn_cl(cfg)?);
        }
        let mut it = actors.iter().cloned();
        let first = it.next().expect("non-empty checked above"); // lint-ok: guarded by emptiness check
        let composed = it.fold(first, |acc, next| compose(&sys, next, acc));
        Ok((composed, actors))
    }

    fn manager_system(&self) -> crate::actor::ActorSystem {
        // the manager spawns its facades on its owning system; reuse it via
        // a tiny probe spawn-free accessor
        self.manager.system_handle()
    }
}

impl Manager {
    pub(crate) fn system_handle(&self) -> crate::actor::ActorSystem {
        // Manager stores the system; expose internally for the builder.
        self.system_ref().clone()
    }
}

/// How a pipeline replica schedules the requests routed to it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PipelineMode {
    /// Start every admitted request immediately: stage N of one request
    /// runs while stage M of another is still in flight on the same
    /// device's in-order queue, so the queue never drains between stages
    /// of a single request (the default, and what the interleaving gate
    /// asserts via [`ExecStats::inflight_peak`](crate::runtime::ExecStats)).
    #[default]
    Interleaved,
    /// One request end-to-end at a time per replica; later arrivals wait
    /// in the driver until the current request's last stage replied. The
    /// composed-actor behavior, kept as the bench baseline.
    LockStep,
}

/// Spawn configuration for a placement-tier pipeline: per-stage
/// [`KernelSpawn`]s in flow order plus a pipeline-wide [`Placement`] knob.
/// Accepted by [`Manager::spawn_pipeline`](super::manager::Manager) — under
/// [`Placement::Replicated`] every stage is compiled and spawned on every
/// replica device and the whole pipeline routes, fails, respawns, and is
/// admission-gated as one unit (see [`super::placement`]).
///
/// Per-stage `placement`, `admission`, and `batching` knobs inside the
/// stage configs are ignored/overridden by the pipeline spawn: the unit of
/// placement is the pipeline.
#[derive(Clone)]
pub struct PipelineSpawn {
    /// Stage spawn configs, flow order.
    pub stages: Vec<KernelSpawn>,
    /// Where the pipeline runs (the stage-level placement knobs are
    /// overridden — a pipeline places as a unit).
    pub placement: Placement,
    /// Stage scheduling on each replica ([`PipelineMode::Interleaved`] is
    /// the default).
    pub mode: PipelineMode,
}

impl PipelineSpawn {
    pub fn new() -> PipelineSpawn {
        PipelineSpawn {
            stages: Vec::new(),
            placement: Placement::Pinned,
            mode: PipelineMode::default(),
        }
    }

    /// Append a stage (flow order).
    pub fn stage(mut self, cfg: KernelSpawn) -> Self {
        self.stages.push(cfg);
        self
    }

    /// Set the pipeline-wide placement.
    pub fn placement(mut self, p: Placement) -> Self {
        self.placement = p;
        self
    }

    /// Set the per-replica stage scheduling mode.
    pub fn mode(mut self, m: PipelineMode) -> Self {
        self.mode = m;
        self
    }
}

impl Default for PipelineSpawn {
    fn default() -> Self {
        Self::new()
    }
}

/// Display label for a pipeline spawn (error messages, logs):
/// `pipeline[a>b>c]`.
pub(crate) fn pipeline_label(stages: &[KernelSpawn]) -> String {
    let names: Vec<&str> = stages.iter().map(|s| s.kernel.as_str()).collect();
    format!("pipeline[{}]", names.join(">"))
}

/// Postprocess helper: fan a stage's `Ref` output into a tuple with the
/// `idx`-th `Ref` argument of that stage's *incoming* message (stages whose
/// successor needs several operands, e.g. `lut(fillslit, sorted)` in the
/// WAH pipeline). The pairing source is the request currently being served
/// — not a shared slot — so concurrent requests and pipeline replicas can
/// never observe each other's references (the `MemRefSlot` set-once hazard
/// this replaced: a per-process slot was clobbered by whichever request or
/// replica wrote last).
pub fn post_pair_from(idx: usize) -> impl Fn(ArgValue, &Message) -> Message + Send + Sync {
    move |out, incoming| {
        let paired = extract_args(incoming).and_then(|args| {
            args.into_iter()
                .filter_map(|a| match a {
                    ArgValue::Ref(r) => Some(r),
                    _ => None,
                })
                .nth(idx)
        });
        match (&out, paired) {
            (ArgValue::Ref(r), Some(e)) => {
                Message::new(vec![ArgValue::Ref(r.clone()), ArgValue::Ref(e)])
            }
            _ => Message::new(out),
        }
    }
}

/// One-shot continuation fired when a request's last stage replied (or any
/// stage failed).
type StageFinish = Box<dyn FnOnce(&mut Ctx, Result<Message, ErrorMsg>) + Send>;

/// Chain one request through the stages from index `i` with request
/// continuations: stage `i`'s reply becomes stage `i+1`'s input; the first
/// error short-circuits to `finish`. A stage that dies mid-request resolves
/// through the same path — its closing mailbox (or its dropped promise)
/// produces an error reply, so the requester always hears back exactly
/// once.
fn drive_stage(
    ctx: &mut Ctx,
    stages: Arc<Vec<ActorRef>>,
    i: usize,
    msg: Message,
    finish: StageFinish,
) {
    if i >= stages.len() {
        finish(ctx, Ok(msg));
        return;
    }
    let next = stages[i].clone();
    ctx.request_msg(&next, msg).then(move |ctx, res| match res {
        Ok(m) => drive_stage(ctx, stages, i + 1, m, finish),
        Err(e) => finish(ctx, Err(e)),
    });
}

/// Requests a lock-step replica has accepted but not started (the current
/// request must finish its last stage first).
#[derive(Default)]
struct LockStepQueue {
    busy: bool,
    waiting: VecDeque<(Message, ResponsePromise, Instant)>,
}

/// Start one request under [`PipelineMode::LockStep`]; its finish
/// continuation delivers the reply, retires the occupancy gauge, and pulls
/// the next waiting request (if any) — one request end-to-end at a time.
fn lockstep_start(
    ctx: &mut Ctx,
    stages: Arc<Vec<ActorRef>>,
    device: Arc<Device>,
    q: Arc<Mutex<LockStepQueue>>,
    msg: Message,
    promise: ResponsePromise,
    t0: Instant,
) {
    let fin_stages = stages.clone();
    let finish: StageFinish = Box::new(move |ctx, res| {
        {
            let stats = device.queue.stats();
            stats.note_pipe_service(t0.elapsed());
            stats.note_pipe_retired(1);
        }
        promise.deliver_result(res);
        let next = {
            let mut g = q.lock().unwrap_or_else(|p| p.into_inner());
            match g.waiting.pop_front() {
                Some(job) => Some(job),
                None => {
                    g.busy = false;
                    None
                }
            }
        };
        if let Some((m, p, t)) = next {
            lockstep_start(ctx, fin_stages.clone(), device, q, m, p, t);
        }
    });
    drive_stage(ctx, stages, 0, msg, finish);
}

/// Spawn the per-replica pipeline driver: the actor the dispatcher
/// delegates routed requests to. It chains the request through the stage
/// facades (all bound to `device`) and answers the original requester via
/// a response promise, accounting occupancy
/// ([`ExecStats::pipe_occupancy`](crate::runtime::ExecStats)) and
/// end-to-end service time (the pipeline EWMA) on the device's stats — the
/// signals pipeline pools steer by. Queue-wait deadlines (`Stamped`
/// requests under an admission `max_queue_wait`) are enforced here, at the
/// replica boundary, exactly like a single-kernel facade's mailbox check;
/// the stage facades behind the driver never see stamps or admission.
pub(crate) fn spawn_pipeline_driver(
    sys: &ActorSystem,
    stages: Vec<ActorRef>,
    device: Arc<Device>,
    mode: PipelineMode,
    admission: Option<Arc<Admission>>,
    label: String,
) -> ActorRef {
    let stages = Arc::new(stages);
    sys.spawn(move |_ctx| {
        let stages = stages.clone();
        let device = device.clone();
        let admission = admission.clone();
        let label = label.clone();
        let lockstep: Arc<Mutex<LockStepQueue>> = Arc::new(Mutex::new(LockStepQueue::default()));
        Behavior::new().on_any(move |ctx, raw| {
            let (stamp, msg) = unstamp(raw);
            if let (Some(at), Some(budget)) = (
                stamp,
                admission.as_ref().and_then(|a| a.cfg().max_queue_wait),
            ) {
                let waited = at.elapsed();
                if waited > budget {
                    // expired in the mailbox: fail fast instead of running
                    // a whole stage chain nobody is waiting for
                    device.queue.stats().note_deadline_failed(1);
                    if let Some(a) = &admission {
                        a.stats
                            .deadline
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    let promise = ctx.make_promise();
                    promise.deliver_err(deadline_error(&label, waited, budget));
                    return Reply::Promised;
                }
            }
            // occupancy rises at admission (lock-step waiters count — they
            // are committed work the steering must see) and falls in the
            // finish continuation, request-for-request
            device.queue.stats().note_pipe_admitted(1);
            let t0 = Instant::now();
            let promise = ctx.make_promise();
            match mode {
                PipelineMode::Interleaved => {
                    let fin_device = device.clone();
                    let finish: StageFinish = Box::new(move |_ctx, res| {
                        {
                            let stats = fin_device.queue.stats();
                            stats.note_pipe_service(t0.elapsed());
                            stats.note_pipe_retired(1);
                        }
                        promise.deliver_result(res);
                    });
                    drive_stage(ctx, stages.clone(), 0, msg.clone(), finish);
                }
                PipelineMode::LockStep => {
                    let start = {
                        let mut g = lockstep.lock().unwrap_or_else(|p| p.into_inner());
                        if g.busy {
                            g.waiting.push_back((msg.clone(), promise, t0));
                            None
                        } else {
                            g.busy = true;
                            Some(promise)
                        }
                    };
                    if let Some(promise) = start {
                        lockstep_start(
                            ctx,
                            stages.clone(),
                            device.clone(),
                            lockstep.clone(),
                            msg.clone(),
                            promise,
                            t0,
                        );
                    }
                }
            }
            Reply::Promised
        })
    })
}
