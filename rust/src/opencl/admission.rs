//! Bounded admission control and deadline-aware dispatch for the
//! placement tier.
//!
//! Every earlier bench is a finite burst: nothing in the dispatch path
//! backpressures a flooding client, so a sustained overload grows batch
//! windows and device queues without bound while every requester waits
//! forever. This module adds the production mechanism — graceful
//! degradation instead of unbounded queue growth:
//!
//! * [`AdmissionConfig`] on [`ReplicaSet`](super::placement::ReplicaSet)
//!   bounds the total admitted-but-unretired work behind a dispatcher
//!   (measured by the same `DevicePool::depth` / `batch_pending` gauges
//!   routing already reads). Past the bound, new requests are rejected
//!   immediately with a typed [`Rejection::Overloaded`] error — an
//!   instant error reply beats an unbounded mailbox — or, under
//!   [`ShedPolicy::DropOldest`], the *stalest* queued request is failed
//!   to admit the new one (fresh work is the work whose deadline is
//!   furthest away).
//! * [`AdmissionConfig::max_queue_wait`] gives every routed request a
//!   local deadline: the dispatcher wraps the message in a [`Stamped`]
//!   envelope carrying its admission instant, and any stage that still
//!   holds the request past the budget — a batch window, the facade's
//!   mailbox — fails it fast with a deadline error instead of occupying
//!   a launch slot for a reply nobody is waiting for. Until now only
//!   `net` enforced a timeout (`remote_actor_timeout`); local dispatch
//!   could stall forever.
//!
//! Error taxonomy: the actor runtime's only error payload is
//! [`ErrorMsg`] (a reason string), so the typed surface is a stable
//! marker token per class plus [`Rejection::of`] to classify a reply.
//! The soak harness and the shedding test matrix both count outcomes
//! through it.
//!
//! Pipeline replicas gauge occupancy per *pipeline*, not per stage: one
//! admitted request becomes N stage launches, so the pool's depth signal
//! is the driver-published `pipe_pending` gauge
//! ([`ExecStats::pipe_occupancy`](crate::runtime::ExecStats)) and the
//! queue-wait stamp is checked once, at the pipeline driver, before any
//! stage runs.

use crate::actor::{ErrorMsg, Message};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

/// What to do with a new request once admitted work sits at the bound.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the incoming request with an `Overloaded` error (default):
    /// newest work is the cheapest to refuse because nothing has been
    /// invested in it yet.
    #[default]
    RejectNew,
    /// Fail the stalest queued-but-unlaunched request with a shed error
    /// and admit the new one: under a deadline-bound workload the oldest
    /// request is the one most likely to be useless by the time it
    /// launches.
    DropOldest,
}

/// Admission bounds for a replicated spawn
/// ([`ReplicaSet::admission`](super::placement::ReplicaSet::admission)).
/// The default is fully unbounded — exactly the pre-admission behavior.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionConfig {
    /// Bound on total admitted-but-unretired requests across the pool
    /// (`None` = unbounded). Compared against the sum of the per-replica
    /// depth gauges (`DevicePool::total_depth`).
    pub max_inflight: Option<u64>,
    /// Per-request queue-wait budget (`None` = no deadline): a request
    /// that has not launched within this long of being routed is failed
    /// fast with a deadline error, including from inside a batch window.
    pub max_queue_wait: Option<Duration>,
    /// Behavior at the `max_inflight` bound.
    pub shed_policy: ShedPolicy,
}

impl AdmissionConfig {
    /// Bound admitted work at `max_inflight`, no deadline, `RejectNew`.
    pub fn bounded(max_inflight: u64) -> AdmissionConfig {
        AdmissionConfig {
            max_inflight: Some(max_inflight),
            ..AdmissionConfig::default()
        }
    }

    /// Set the per-request queue-wait deadline.
    pub fn deadline(mut self, max_queue_wait: Duration) -> AdmissionConfig {
        self.max_queue_wait = Some(max_queue_wait);
        self
    }

    /// Set the at-the-bound policy.
    pub fn shed(mut self, policy: ShedPolicy) -> AdmissionConfig {
        self.shed_policy = policy;
        self
    }

    /// True when this config never rejects, sheds, or expires anything.
    pub fn is_unbounded(&self) -> bool {
        self.max_inflight.is_none() && self.max_queue_wait.is_none()
    }
}

/// Monotonic outcome counters for one admission domain (one replicated
/// spawn). Exposed on
/// [`ReplicatedHandle::admission`](super::placement::ReplicatedHandle)
/// so benches and tests can read shed/deadline counts without parsing
/// error strings.
#[derive(Debug, Default)]
pub struct AdmissionStats {
    /// Requests rejected at the bound under [`ShedPolicy::RejectNew`]
    /// (or under `DropOldest` when no queued victim existed).
    pub overloaded: AtomicU64,
    /// Queued requests failed by [`ShedPolicy::DropOldest`] to admit
    /// newer work.
    pub shed: AtomicU64,
    /// Requests failed fast because their queue wait exceeded
    /// [`AdmissionConfig::max_queue_wait`].
    pub deadline: AtomicU64,
}

impl AdmissionStats {
    pub fn overloaded_count(&self) -> u64 {
        self.overloaded.load(Ordering::Relaxed)
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn deadline_count(&self) -> u64 {
        self.deadline.load(Ordering::Relaxed)
    }
}

/// A queue the admission layer can shed from: any stage holding
/// admitted-but-unlaunched requests (today: the per-device batch windows
/// of `batch.rs`). Registered weakly so a dying facade unregisters
/// itself by dropping its state.
pub(crate) trait ShedQueue: Send + Sync {
    /// Admission instant of this queue's stalest queued request, if any.
    fn oldest(&self) -> Option<Instant>;
    /// Fail this queue's stalest queued request with a shed error;
    /// returns true iff a victim was shed.
    fn shed_oldest(&self) -> bool;
}

/// Shared admission state of one replicated spawn: the config, the
/// outcome counters, and the registry of sheddable queues. One instance
/// is created per [`spawn_cl_replicated`] call and shared by the
/// dispatcher, every replica facade (including respawned ones), and the
/// caller via `ReplicatedHandle`.
///
/// [`spawn_cl_replicated`]: super::manager::Manager::spawn_cl_replicated
#[derive(Default)]
pub struct Admission {
    cfg: AdmissionConfig,
    /// Outcome counters (public: the soak harness reads them directly).
    pub stats: AdmissionStats,
    queues: Mutex<Vec<Weak<dyn ShedQueue>>>,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission {
            cfg,
            ..Admission::default()
        }
    }

    pub fn cfg(&self) -> AdmissionConfig {
        self.cfg
    }

    /// Register a sheddable queue (called by each batching facade at
    /// spawn; respawned replicas re-register because the respawn base
    /// spawn config carries this `Admission`). Dead entries are pruned
    /// lazily on the next shed attempt.
    pub(crate) fn register(&self, q: Weak<dyn ShedQueue>) {
        let mut qs = self.queues.lock().unwrap_or_else(|p| p.into_inner());
        qs.retain(|w| w.strong_count() > 0);
        qs.push(q);
    }

    /// Admission decision for one extracted request, given the pool's
    /// current admitted-but-unretired depth. `Ok(())` admits; `Err`
    /// carries the typed `Overloaded` reply for the requester.
    ///
    /// Under [`ShedPolicy::DropOldest`] the bound is enforced by failing
    /// the globally stalest queued request across all registered queues;
    /// only when no queued victim exists (all admitted work is already
    /// launched and cannot be recalled) does the new request bounce.
    pub fn try_admit(&self, depth: u64, kernel: &str) -> Result<(), ErrorMsg> {
        let Some(max) = self.cfg.max_inflight else {
            return Ok(());
        };
        if depth < max {
            return Ok(());
        }
        if self.cfg.shed_policy == ShedPolicy::DropOldest && self.shed_stalest() {
            self.stats.shed.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.stats.overloaded.fetch_add(1, Ordering::Relaxed);
        Err(overloaded_error(kernel, depth, max))
    }

    /// Shed the globally stalest queued request across every live
    /// registered queue. Returns true iff a victim was shed.
    fn shed_stalest(&self) -> bool {
        let candidates: Vec<Arc<dyn ShedQueue>> = {
            let mut qs = self.queues.lock().unwrap_or_else(|p| p.into_inner());
            qs.retain(|w| w.strong_count() > 0);
            qs.iter().filter_map(|w| w.upgrade()).collect()
        };
        let mut best: Option<(&Arc<dyn ShedQueue>, Instant)> = None;
        for q in &candidates {
            if let Some(t) = q.oldest() {
                if best.map(|(_, b)| t < b).unwrap_or(true) {
                    best = Some((q, t));
                }
            }
        }
        best.map(|(q, _)| q.shed_oldest()).unwrap_or(false)
    }
}

/// Dispatcher-to-replica envelope carrying the admission instant of a
/// routed request. Only wrapped when the spawn has a `max_queue_wait`
/// (the deadline-free path pays nothing); replica facades unwrap with
/// [`unstamp`] before extraction, so preprocess hooks and `extract_args`
/// always see the original message.
pub struct Stamped {
    /// When the dispatcher admitted the request.
    pub at: Instant,
    /// The original request message.
    pub inner: Message,
}

/// Split a possibly-[`Stamped`] message into its admission instant and
/// the payload message every downstream stage should interpret.
pub(crate) fn unstamp(msg: &Message) -> (Option<Instant>, &Message) {
    match msg.downcast_ref::<Stamped>() {
        Some(s) => (Some(s.at), &s.inner),
        None => (None, msg),
    }
}

// Stable marker tokens: `ErrorMsg` is a bare reason string, so these are
// the typed error surface. `Rejection::of` is the only parser.
const OVERLOADED_TOKEN: &str = "overloaded:";
const SHED_TOKEN: &str = "shed by DropOldest:";
const DEADLINE_TOKEN: &str = "deadline exceeded:";

pub(crate) fn overloaded_error(kernel: &str, depth: u64, max: u64) -> ErrorMsg {
    ErrorMsg::new(format!(
        "kernel {kernel}: {OVERLOADED_TOKEN} {depth} admitted requests at \
         max_inflight {max}; rejecting new work"
    ))
}

pub(crate) fn shed_error(kernel: &str, waited: Duration) -> ErrorMsg {
    ErrorMsg::new(format!(
        "kernel {kernel}: {SHED_TOKEN} queued {waited:?} and dropped to \
         admit newer work at the admission bound"
    ))
}

pub(crate) fn deadline_error(kernel: &str, waited: Duration, budget: Duration) -> ErrorMsg {
    ErrorMsg::new(format!(
        "kernel {kernel}: {DEADLINE_TOKEN} queued {waited:?} with \
         max_queue_wait {budget:?}; failed fast before launch"
    ))
}

/// Typed classification of an admission-layer error reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejection {
    /// Rejected at the admission bound ([`ShedPolicy::RejectNew`]).
    Overloaded,
    /// Shed from a queue by [`ShedPolicy::DropOldest`].
    Shed,
    /// Failed fast after exceeding [`AdmissionConfig::max_queue_wait`].
    Deadline,
}

impl Rejection {
    /// Classify an [`ErrorMsg`]; `None` for errors the admission layer
    /// did not produce (routing errors, broken promises, timeouts, ...).
    pub fn of(e: &ErrorMsg) -> Option<Rejection> {
        if e.reason.contains(OVERLOADED_TOKEN) {
            Some(Rejection::Overloaded)
        } else if e.reason.contains(SHED_TOKEN) {
            Some(Rejection::Shed)
        } else if e.reason.contains(DEADLINE_TOKEN) {
            Some(Rejection::Deadline)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_unbounded_and_admits_everything() {
        let cfg = AdmissionConfig::default();
        assert!(cfg.is_unbounded());
        let adm = Admission::new(cfg);
        assert!(adm.try_admit(u64::MAX, "k").is_ok());
        assert_eq!(adm.stats.overloaded_count(), 0);
    }

    #[test]
    fn builders_compose() {
        let cfg = AdmissionConfig::bounded(8)
            .deadline(Duration::from_millis(50))
            .shed(ShedPolicy::DropOldest);
        assert_eq!(cfg.max_inflight, Some(8));
        assert_eq!(cfg.max_queue_wait, Some(Duration::from_millis(50)));
        assert_eq!(cfg.shed_policy, ShedPolicy::DropOldest);
        assert!(!cfg.is_unbounded());
    }

    #[test]
    fn reject_new_bounces_at_the_bound() {
        let adm = Admission::new(AdmissionConfig::bounded(4));
        assert!(adm.try_admit(3, "k").is_ok());
        let err = adm.try_admit(4, "k").unwrap_err();
        assert_eq!(Rejection::of(&err), Some(Rejection::Overloaded));
        assert!(err.reason.contains("kernel k"));
        assert_eq!(adm.stats.overloaded_count(), 1);
        assert_eq!(adm.stats.shed_count(), 0);
    }

    /// Fake sheddable queue: a FIFO of admission instants.
    struct FakeQueue {
        pending: Mutex<Vec<Instant>>,
        shed_calls: AtomicU64,
    }

    impl FakeQueue {
        fn with(pending: Vec<Instant>) -> Arc<FakeQueue> {
            Arc::new(FakeQueue {
                pending: Mutex::new(pending),
                shed_calls: AtomicU64::new(0),
            })
        }
    }

    impl ShedQueue for FakeQueue {
        fn oldest(&self) -> Option<Instant> {
            self.pending.lock().unwrap().first().copied()
        }

        fn shed_oldest(&self) -> bool {
            let mut p = self.pending.lock().unwrap();
            if p.is_empty() {
                return false;
            }
            p.remove(0);
            self.shed_calls.fetch_add(1, Ordering::Relaxed);
            true
        }
    }

    #[test]
    fn drop_oldest_sheds_from_the_queue_with_the_stalest_request() {
        let adm = Admission::new(AdmissionConfig::bounded(2).shed(ShedPolicy::DropOldest));
        let t0 = Instant::now();
        let older = FakeQueue::with(vec![t0, t0 + Duration::from_millis(5)]);
        let newer = FakeQueue::with(vec![t0 + Duration::from_millis(1)]);
        adm.register(Arc::downgrade(&(older.clone() as Arc<dyn ShedQueue>)));
        adm.register(Arc::downgrade(&(newer.clone() as Arc<dyn ShedQueue>)));
        assert!(adm.try_admit(2, "k").is_ok());
        assert_eq!(older.shed_calls.load(Ordering::Relaxed), 1);
        assert_eq!(newer.shed_calls.load(Ordering::Relaxed), 0);
        assert_eq!(adm.stats.shed_count(), 1);
        // next stalest is `newer`'s t0+1ms entry
        assert!(adm.try_admit(2, "k").is_ok());
        assert_eq!(newer.shed_calls.load(Ordering::Relaxed), 1);
        assert_eq!(adm.stats.shed_count(), 2);
    }

    #[test]
    fn drop_oldest_without_a_victim_falls_back_to_rejection() {
        let adm = Admission::new(AdmissionConfig::bounded(1).shed(ShedPolicy::DropOldest));
        let empty = FakeQueue::with(vec![]);
        adm.register(Arc::downgrade(&(empty.clone() as Arc<dyn ShedQueue>)));
        let err = adm.try_admit(1, "k").unwrap_err();
        assert_eq!(Rejection::of(&err), Some(Rejection::Overloaded));
        assert_eq!(adm.stats.overloaded_count(), 1);
        assert_eq!(adm.stats.shed_count(), 0);
    }

    #[test]
    fn dead_queues_are_pruned_from_the_registry() {
        let adm = Admission::new(AdmissionConfig::bounded(1).shed(ShedPolicy::DropOldest));
        let q = FakeQueue::with(vec![Instant::now()]);
        adm.register(Arc::downgrade(&(q.clone() as Arc<dyn ShedQueue>)));
        drop(q); // facade died: the weak reference now dangles
        let err = adm.try_admit(1, "k").unwrap_err();
        assert_eq!(Rejection::of(&err), Some(Rejection::Overloaded));
        assert!(adm
            .queues
            .lock()
            .unwrap()
            .is_empty(), "dangling registration must be pruned");
    }

    #[test]
    fn rejection_classifies_every_marker_and_nothing_else() {
        let o = overloaded_error("k", 9, 8);
        let s = shed_error("k", Duration::from_millis(3));
        let d = deadline_error("k", Duration::from_millis(7), Duration::from_millis(5));
        assert_eq!(Rejection::of(&o), Some(Rejection::Overloaded));
        assert_eq!(Rejection::of(&s), Some(Rejection::Shed));
        assert_eq!(Rejection::of(&d), Some(Rejection::Deadline));
        let other = ErrorMsg::new("request timed out".into());
        assert_eq!(Rejection::of(&other), None);
    }

    #[test]
    fn unstamp_round_trips_and_passes_plain_messages_through() {
        let at = Instant::now();
        let plain = Message::new(vec![1u32, 2, 3]);
        let (none, inner) = unstamp(&plain);
        assert!(none.is_none());
        assert!(inner.downcast_ref::<Vec<u32>>().is_some());
        let stamped = Message::new(Stamped {
            at,
            inner: Message::new(vec![4u32]),
        });
        let (some, inner) = unstamp(&stamped);
        assert_eq!(some, Some(at));
        assert_eq!(inner.downcast_ref::<Vec<u32>>().unwrap(), &vec![4u32]);
    }
}
